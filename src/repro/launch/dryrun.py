import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any jax import: jax locks the device
# count at first init.  512 placeholder host devices back the production
# mesh; nothing is ever allocated (lower/compile only).

import argparse
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCHS, INPUT_SHAPES, get_arch
from repro.configs.base import ArchConfig, InputShape
from repro.launch import steps as steps_mod
from repro.launch.mesh import make_production_mesh
from repro.models import model as model_mod
from repro.optim import init_opt
from repro.sharding import hints
from repro.sharding.specs import (batch_axes, batch_specs, cache_specs,
                                  opt_state_specs, param_specs,
                                  sanitize_specs)

# TPU v5e hardware constants (single chip)
HW = dict(peak_flops=197e12, hbm_bw=819e9, ici_bw=50e9)


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum result bytes of every collective op in the partitioned HLO —
    via the one shared parser in ``repro.analysis.hlo`` (async pairs count
    once)."""
    from repro.analysis import hlo as hlo_mod
    return hlo_mod.byte_totals(hlo_text)


def _shard(mesh, spec_tree, abstract_tree=None):
    if abstract_tree is not None:
        spec_tree = sanitize_specs(spec_tree, abstract_tree, mesh)
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def abstract_params(cfg: ArchConfig):
    return jax.eval_shape(
        lambda: model_mod.init_params(cfg, jax.random.PRNGKey(0), jnp.bfloat16))


def _long_window(cfg: ArchConfig, shape: InputShape) -> Optional[int]:
    if shape.name == "long_500k" and cfg.long_context_mode == "window":
        return 4096
    return None


def model_flops(cfg: ArchConfig, shape: InputShape) -> float:
    n = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch  # decode: one token per sequence


def lower_combo(arch: str, shape_name: str, *, multi_pod: bool = False,
                save_hlo: Optional[str] = None,
                override_cfg: Optional[ArchConfig] = None,
                variant: str = "opt") -> Dict[str, Any]:
    """variant='baseline': paper-faithful naive lowering (no vocab padding,
    FSDP also while serving, no head padding).  variant='opt': the §Perf
    optimized configuration."""
    cfg = override_cfg or get_arch(arch)
    shape = INPUT_SHAPES[shape_name]
    rec: Dict[str, Any] = dict(arch=arch, shape=shape_name, variant=variant,
                               mesh="2x16x16" if multi_pod else "16x16")
    if variant == "baseline":
        cfg = cfg.replace(pad_vocab=False)

    if shape.name == "long_500k" and cfg.long_context_mode == "skip":
        rec["status"] = "skipped"
        rec["reason"] = ("enc-dec ASR model: 524k-token autoregressive decode "
                         "is not a meaningful workload (DESIGN.md)")
        return rec

    window = _long_window(cfg, shape)
    serve = shape.kind in ("prefill", "decode")
    masks = None
    if serve and variant != "baseline":
        from repro.sharding.padding import pad_heads_for_serving
        cfg, masks = pad_heads_for_serving(cfg)
        rec["head_padding"] = masks is not None
    fsdp_flag = cfg.fsdp if (shape.kind == "train" or variant == "baseline") \
        else cfg.serve_fsdp
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    pspecs = param_specs(cfg, fsdp=fsdp_flag, multi_pod=multi_pod)
    params_abs = abstract_params(cfg)
    pshard = _shard(mesh, pspecs, params_abs)
    bspecs = batch_specs(cfg, multi_pod, shape.kind)
    t0 = time.time()

    with mesh:
        pol = hints.megatron_policy(batch_axes(multi_pod))
        with hints.policy(pol):
            if shape.kind == "train":
                step_fn = steps_mod.make_train_step(cfg)
                mdt = jnp.bfloat16 if cfg.momentum_dtype == "bfloat16" \
                    else jnp.float32
                opt_abs = jax.eval_shape(
                    lambda p: init_opt(p, cfg.optimizer, mdt), params_abs)
                oshard = _shard(mesh, opt_state_specs(
                    cfg, pspecs, cfg.optimizer == "adamw"), opt_abs)
                batch_abs = steps_mod.input_specs(cfg, shape)
                bshard = _shard(mesh, {k: bspecs[k] for k in batch_abs},
                                batch_abs)
                # donate params + optimizer state: new values alias the
                # old buffers (true on TPU; CPU memory_analysis reports the
                # aliased outputs under temp)
                jitted = jax.jit(
                    step_fn,
                    in_shardings=(pshard, oshard, bshard, None),
                    out_shardings=(pshard, oshard, None),
                    donate_argnums=(0, 1))
                lowered = jitted.lower(
                    params_abs, opt_abs, batch_abs,
                    jax.ShapeDtypeStruct((), jnp.int32))
            elif shape.kind == "prefill":
                step_fn = steps_mod.make_prefill_step(cfg, window=window,
                                                      masks=masks)
                batch_abs = steps_mod.input_specs(cfg, shape)
                bshard = _shard(mesh, {k: bspecs[k] for k in batch_abs},
                                batch_abs)
                out_abs = jax.eval_shape(step_fn, params_abs, batch_abs)
                b = batch_axes(multi_pod)
                baxes = b if len(b) > 1 else b[0]
                out_specs = (P(baxes, None, "model"),
                             cache_specs(cfg, multi_pod))
                if cfg.encoder is not None:
                    out_specs = out_specs + (P(baxes, None, None),)
                outs = _shard(mesh, out_specs, out_abs)
                jitted = jax.jit(step_fn, in_shardings=(pshard, bshard),
                                 out_shardings=outs)
                lowered = jitted.lower(params_abs, batch_abs)
            else:  # decode
                step_fn = steps_mod.make_decode_step(cfg, window=window,
                                                     masks=masks)
                caches_abs = steps_mod.decode_cache_specs(cfg, shape, window=window)
                cshard = _shard(mesh, cache_specs(cfg, multi_pod), caches_abs)
                batch_abs = steps_mod.input_specs(cfg, shape)
                b = batch_axes(multi_pod)
                baxes = b if len(b) > 1 else b[0]
                tshard = _shard(mesh, P(baxes, None), batch_abs["tokens"])
                args = [params_abs, caches_abs, batch_abs["tokens"]]
                in_sh = [pshard, cshard, tshard]
                if cfg.encoder is not None:
                    enc_abs = jax.ShapeDtypeStruct(
                        (shape.global_batch, cfg.encoder.n_frames, cfg.d_model),
                        jnp.bfloat16)
                    args.append(enc_abs)
                    in_sh.append(_shard(mesh, P(baxes, None, None), enc_abs))
                out_abs = jax.eval_shape(step_fn, *args)
                outs = _shard(mesh, (P(baxes, None, "model"),
                                     cache_specs(cfg, multi_pod)), out_abs)
                # donate the caches: in-place update halves serving memory
                jitted = jax.jit(step_fn, in_shardings=tuple(in_sh),
                                 out_shardings=outs, donate_argnums=(1,))
                lowered = jitted.lower(*args)

            compiled = lowered.compile()

    rec["lower_compile_s"] = round(time.time() - t0, 1)
    try:
        ma = compiled.memory_analysis()
        rec["memory"] = dict(
            argument_bytes=ma.argument_size_in_bytes,
            output_bytes=ma.output_size_in_bytes,
            temp_bytes=ma.temp_size_in_bytes,
            peak_bytes=(ma.argument_size_in_bytes + ma.temp_size_in_bytes),
        )
    except Exception as e:                            # pragma: no cover
        rec["memory"] = {"error": str(e)}
    try:
        ca = compiled.cost_analysis()
        rec["cost"] = {k: ca.get(k) for k in
                       ("flops", "bytes accessed", "transcendentals")
                       if k in ca}
    except Exception as e:                            # pragma: no cover
        rec["cost"] = {"error": str(e)}

    hlo = compiled.as_text()
    rec["collectives"] = collective_bytes(hlo)
    rec["hlo_bytes"] = len(hlo)
    if save_hlo:
        with open(save_hlo, "w") as f:
            f.write(hlo)

    # Roofline terms.  compiled.cost_analysis() and the HLO module are
    # PER-DEVICE after SPMD partitioning (verified: flops*chips ==
    # 6*N*tokens for dense train steps), so the "/ chips" of the global
    # formula is already applied; the per-chip peaks divide directly.
    flops = float(rec.get("cost", {}).get("flops") or 0.0)
    bytes_acc = float(rec.get("cost", {}).get("bytes accessed") or 0.0)
    coll = float(rec["collectives"].get("total", 0))
    mf = model_flops(cfg, shape)
    rec["roofline"] = dict(
        chips=chips,
        compute_s=flops / HW["peak_flops"],
        memory_s=bytes_acc / HW["hbm_bw"],
        collective_s=coll / HW["ici_bw"],
        model_flops=mf,
        hlo_flops_global=flops * chips,
        useful_flops_ratio=(mf / (flops * chips)) if flops else None,
    )
    terms = {k: rec["roofline"][k] for k in ("compute_s", "memory_s", "collective_s")}
    rec["roofline"]["bottleneck"] = max(terms, key=terms.get)
    rec["status"] = "ok"
    return rec


def main() -> None:
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--save-hlo", default=None)
    ap.add_argument("--variant", default="opt", choices=["opt", "baseline"])
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    archs = [a for a in ARCHS if a != "fedfa-paper-transformer"] \
        if (args.all or args.arch is None) else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.all or args.shape is None) else [args.shape]

    for a in archs:
        for s in shapes:
            tag = f"{a}_{s}_{'2x16x16' if args.multi_pod else '16x16'}"
            path = os.path.join(args.out, tag + ".json")
            if os.path.exists(path):
                print(f"[skip] {tag} (cached)")
                continue
            print(f"[dryrun] {tag} ...", flush=True)
            try:
                rec = lower_combo(a, s, multi_pod=args.multi_pod,
                                  save_hlo=args.save_hlo,
                                  variant=args.variant)
            except Exception as e:
                rec = dict(arch=a, shape=s, status="error",
                           error=f"{type(e).__name__}: {e}",
                           trace=traceback.format_exc()[-2000:])
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
            print(f"  -> {rec['status']} "
                  f"({rec.get('lower_compile_s', '-')}s; "
                  f"mem={rec.get('memory', {}).get('peak_bytes', '-')}; "
                  f"bottleneck={rec.get('roofline', {}).get('bottleneck', '-')})",
                  flush=True)


if __name__ == "__main__":
    main()
