"""Batched serving engine: prefill + greedy/temperature decode over a KV
(or state) cache.  The same step functions the dry-run lowers for the
production mesh run here at CPU scale.
"""
from __future__ import annotations

import argparse
import time
from typing import Dict, Optional

import numpy as np


class Engine:
    """Minimal batched inference engine around prefill/decode_step."""

    def __init__(self, cfg, params, *, window: Optional[int] = None,
                 capacity: int = 512):
        import jax
        from repro.launch.steps import make_decode_step
        from repro.models import model as model_mod
        self.cfg, self.params = cfg, params
        self.window = window
        self.capacity = capacity
        self._model = model_mod
        self._decode = jax.jit(make_decode_step(cfg, window=window))
        self._jax = jax

    def generate(self, tokens, *, max_new: int = 32, frames=None,
                 patches=None, temperature: float = 0.0, seed: int = 0):
        jax, jnp = self._jax, self._jax.numpy
        B = tokens.shape[0]
        batch = {"tokens": jnp.asarray(tokens)}
        if frames is not None:
            batch["frames"] = jnp.asarray(frames)
        if patches is not None:
            batch["patches"] = jnp.asarray(patches)
        logits, caches, enc_out = self._model.prefill(
            self.params, self.cfg, batch, capacity=self.capacity,
            window=self.window)
        key = jax.random.PRNGKey(seed)
        outs = []
        tok = self._pick(logits[:, -1], temperature, key)
        outs.append(np.asarray(tok))
        for i in range(max_new - 1):
            logits, caches = self._decode(self.params, caches, tok, enc_out)
            key = jax.random.fold_in(key, i)
            tok = self._pick(logits[:, -1], temperature, key)
            outs.append(np.asarray(tok))
        return np.concatenate(outs, axis=1)   # each step yields (B, 1)

    def _pick(self, logits, temperature, key):
        jnp = self._jax.numpy
        if temperature <= 0.0:
            return jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        p = self._jax.random.categorical(key, logits / temperature)
        return p[:, None].astype(jnp.int32)


def main() -> None:
    import jax
    from repro.configs import get_arch
    from repro.data import synthetic
    from repro.models import model as model_mod

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=32)
    args = ap.parse_args()

    cfg = get_arch(args.arch).reduced()
    params = model_mod.init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, capacity=args.prompt_len + args.max_new + 8,
                 window=cfg.attn_window)
    prompts = synthetic.lm_stream(cfg.vocab_size, args.batch, args.prompt_len,
                                  seed=0)
    kw = {}
    if cfg.encoder is not None:
        kw["frames"] = 0.02 * np.random.randn(
            args.batch, cfg.encoder.n_frames, cfg.d_model).astype(np.float32)
    if cfg.vision is not None:
        kw["patches"] = 0.02 * np.random.randn(
            args.batch, cfg.vision.n_patches, cfg.vision.vit_dim).astype(np.float32)
    t0 = time.time()
    out = eng.generate(prompts, max_new=args.max_new, **kw)
    dt = time.time() - t0
    print(f"generated {out.shape} in {dt:.1f}s "
          f"({args.batch * args.max_new / dt:.1f} tok/s)")
    print(out[:2])


if __name__ == "__main__":
    main()
