"""Federated training driver.

Two modes:
  * ``--mode fl``     — the paper's workload: synthetic federated rounds with
    heterogeneous client architectures, FedFA (or baseline) aggregation,
    optional backdoor attackers.  This is what examples/ and benchmarks/
    drive at CPU scale.
  * ``--mode dense``  — plain distributed pretraining of one architecture
    (the e2e driver for (b): train a ~100M model for a few hundred steps).

For multi-host production the same functions are jitted with the meshes
from repro.launch.mesh; on this container they run on CPU with a host mesh.
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Optional

import numpy as np


def run_dense(arch: str, steps: int, batch: int, seq_len: int,
              log_every: int = 10, reduced: bool = True,
              seed: int = 0) -> dict:
    import jax
    import jax.numpy as jnp
    from repro.configs import get_arch
    from repro.data import synthetic
    from repro.launch.steps import make_train_step
    from repro.models import model as model_mod
    from repro.optim import init_opt

    cfg = get_arch(arch)
    if reduced:
        cfg = cfg.reduced()
    cfg = cfg.replace(grad_accum=1)
    key = jax.random.PRNGKey(seed)
    params = model_mod.init_params(cfg, key)
    opt = init_opt(params, cfg.optimizer)
    step_fn = jax.jit(make_train_step(cfg, total_steps=steps))

    data = synthetic.lm_stream(cfg.vocab_size, steps * batch, seq_len, seed=seed)
    losses = []
    t0 = time.time()
    for s in range(steps):
        tok = jnp.asarray(data[s * batch:(s + 1) * batch])
        batch_d = {"tokens": tok}
        if cfg.vision is not None:
            batch_d["patches"] = 0.02 * jax.random.normal(
                jax.random.fold_in(key, s),
                (batch, cfg.vision.n_patches, cfg.vision.vit_dim))
        if cfg.encoder is not None:
            batch_d["frames"] = 0.02 * jax.random.normal(
                jax.random.fold_in(key, s),
                (batch, cfg.encoder.n_frames, cfg.d_model))
        params, opt, loss = step_fn(params, opt, batch_d, jnp.asarray(s))
        losses.append(float(loss))
        if s % log_every == 0:
            print(f"step {s:4d}  loss {losses[-1]:.4f}  "
                  f"({(time.time()-t0)/(s+1):.2f}s/step)", flush=True)
    return {"arch": arch, "losses": losses,
            "first": float(np.mean(losses[:5])),
            "last": float(np.mean(losses[-5:]))}


def client_arch_pool(cfg, mode: str, fracs=(0.25, 0.5, 0.75, 1.0)):
    """Paper's three flexibility regimes: depth-only (vs FlexiFed),
    width-only (vs HeteroFL), both (vs NeFL)."""
    import numpy as np
    from repro.models.masks import ClientArch, max_section_depths
    maxd = max_section_depths(cfg)
    depths = lambda f: tuple(max(1, int(np.ceil(f * m))) for m in maxd)
    if mode == "width":
        return [ClientArch(w, maxd) for w in fracs]
    if mode == "depth":
        return [ClientArch(1.0, depths(f)) for f in fracs]
    pool = [ClientArch(w, depths(f)) for w, f in
            [(0.25, 0.5), (0.5, 0.5), (0.5, 1.0), (0.75, 0.75), (1.0, 1.0)]]
    return pool


def run_fl(arch: str, rounds: int, n_clients: int, *, strategy: str = "fedfa",
           malicious_frac: float = 0.0, attack_lambda: float = 1.0,
           noniid: bool = False, local_steps: int = 2, batch: int = 4,
           seq_len: int = 32, n_classes: int = 10, lr: float = 0.05,
           participation: float = 0.5, seed: int = 0,
           eval_every: int = 5, task: str = "cls",
           width_mults=(0.25, 0.5, 0.75, 1.0),
           arch_mode: str = "width", agg_engine: str = "flat",
           driver: str = "resident", merge_k: int = 0,
           staleness_max: int = 4,
           async_deadline: float = float("inf"),
           mesh: Optional[str] = None,
           use_kernel: Optional[bool] = None,
           interpret: bool = False, update_dtype: str = "f32",
           ckpt: Optional[str] = None,
           quiet: bool = False) -> dict:
    import jax
    import jax.numpy as jnp
    from repro.configs import get_arch
    from repro.core.server import (ClientSpec, FLConfig, fl_round,
                                   make_client_specs, select_clients)
    from repro.data import partition as part_mod
    from repro.data import pipeline, synthetic
    from repro.models import model as model_mod
    from repro.models.masks import ClientArch, max_section_depths

    cfg = get_arch(arch).reduced().replace(n_layers=4, n_sections=2)
    # 4 layers / 2 sections so DEPTH flexibility is real (reduced() alone
    # gives 2 layers -> both sections have max depth 1 and the depth pool
    # degenerates to homogeneous clients).
    if task == "cls":
        cfg = cfg.replace(vocab_size=max(64, n_classes), tie_embeddings=False)
    key = jax.random.PRNGKey(seed)
    rng = np.random.default_rng(seed)
    params = model_mod.init_params(cfg, key)

    archs = client_arch_pool(cfg, arch_mode, width_mults)
    parts = (part_mod.noniid_partition(n_clients, n_classes, seed=seed)
             if noniid else part_mod.iid_partition(n_clients, n_classes, seed=seed))
    class_masks = [part_mod.client_class_mask(p, cfg.padded_vocab) for p in parts] \
        if noniid else None
    specs = make_client_specs(cfg, n_clients, archs=archs,
                              malicious_frac=malicious_frac,
                              class_masks=class_masks, seed=seed)
    profiles = synthetic.make_class_profiles(n_classes, cfg.vocab_size, seed=seed)
    fl = FLConfig(participation=participation, local_steps=local_steps,
                  lr=lr, attack_lambda=attack_lambda, strategy=strategy,
                  task=task, agg_engine=agg_engine, use_kernel=use_kernel,
                  interpret=interpret, update_dtype=update_dtype, seed=seed)

    hist = {"round": [], "loss": [], "global_acc": [], "local_acc": []}
    test = pipeline.eval_batch_cls(n_classes, cfg.vocab_size, 256, seq_len,
                                   profiles, seed=seed + 99)
    test_j = {k: jnp.asarray(v) for k, v in test.items()}

    @jax.jit
    def global_acc(p):
        logits, _ = model_mod.forward(p, cfg, {"tokens": test_j["tokens"]},
                                      remat=False)
        pred = jnp.argmax(jnp.mean(logits[..., :n_classes], axis=1), -1)
        return jnp.mean((pred == test_j["labels"]).astype(jnp.float32))

    # local personalization metric (non-IID): extracted client models on
    # class-restricted local test sets (paper's "average local accuracy")
    from repro.core.masking import apply_mask_tree, axis_mask_tree
    local_eval = []
    for ci in range(min(4, n_clients)):
        d = pipeline.eval_batch_cls(n_classes, cfg.vocab_size, 64, seq_len,
                                    profiles, classes=parts[ci]["classes"],
                                    seed=seed + 300 + ci)
        local_eval.append((ci, {k: jnp.asarray(v) for k, v in d.items()}))

    def local_acc(p):
        accs = []
        for ci, d in local_eval:
            s = specs[ci]
            masks = s.arch.masks(cfg)
            gates = s.arch.gates(cfg)
            pm = apply_mask_tree(p, axis_mask_tree(cfg, masks))
            logits, _ = model_mod.forward(pm, cfg, {"tokens": d["tokens"]},
                                          masks=masks, gates=gates, remat=False)
            lg = jnp.mean(logits[..., :n_classes], axis=1)
            if s.class_mask is not None:
                cm = jnp.asarray(s.class_mask[:n_classes])
                lg = jnp.where(cm[None] > 0, lg, -1e30)
            accs.append(float(jnp.mean(
                (jnp.argmax(lg, -1) == d["labels"]).astype(jnp.float32))))
        return float(np.mean(accs))

    def round_data(r):
        """Host-side per-round cohort selection + batch synthesis (shared by
        both drivers so they see identical rounds)."""
        sel = select_clients(n_clients, participation, rng)
        batches_np = pipeline.round_batches_cls(
            parts, sel, n_classes, cfg.vocab_size, local_steps=local_steps,
            batch=batch, seq_len=seq_len, profiles=profiles,
            seed=seed * 1000 + r)
        return ([specs[i] for i in sel],
                {k: jnp.asarray(v) for k, v in batches_np.items()})

    def record_eval(r, loss, p):
        acc = float(global_acc(p))
        lacc = local_acc(p)
        hist["round"].append(r)
        hist["loss"].append(loss)
        hist["global_acc"].append(acc)
        hist["local_acc"].append(lacc)
        if not quiet:
            print(f"[{strategy}/{arch_mode}] round {r:3d} "
                  f"loss {loss:.4f} global_acc {acc:.3f} "
                  f"local_acc {lacc:.3f}", flush=True)

    if driver in ("resident", "async") and agg_engine != "flat":
        if not quiet:
            print(f"{driver} driver is flat-native; falling back to the "
                  "per-round driver for agg_engine=tree", flush=True)
        driver = "per-round"
    if update_dtype != "f32" and driver == "per-round":
        # quantized admission lives in the resident/async flat programs;
        # the per-round driver re-dispatches trees and has no cohort pool
        # to quantize into
        if not quiet:
            print(f"--update-dtype {update_dtype} needs the resident or "
                  "async driver; running the per-round driver at f32",
                  flush=True)
        import dataclasses
        fl = dataclasses.replace(fl, update_dtype="f32")

    from repro.launch.mesh import get_mesh
    mesh_obj = get_mesh(mesh)
    if mesh_obj is not None and driver not in ("resident", "async"):
        if not quiet:
            print("--mesh shards the resident/async drivers' cohort axis; "
                  "the per-round driver runs unsharded", flush=True)
        mesh_obj = None

    if driver == "resident":
        from repro.core.round import run_rounds
        params, _ = run_rounds(params, cfg, fl, rounds, round_data, key,
                               eval_every=eval_every, eval_fn=record_eval,
                               ckpt_path=ckpt, mesh=mesh_obj)
    elif driver == "async":
        # continuous arrivals from the trace-driven population simulator:
        # clients keep their round_data specs/batches, but WHEN they arrive
        # comes from hashed device-class latency/availability traces, and
        # merges fire on merge_k arrivals or the deadline (rounds counts
        # MERGES here, so histories line up with the sync drivers)
        from repro.core.async_round import AsyncConfig, run_async
        from repro.sim import ClientPopulation, PopulationSource
        population = ClientPopulation(n_clients, seed=seed)
        capacity = max(1, int(round(participation * n_clients)))

        def batch_fn(d, ids):
            batches_np = pipeline.round_batches_cls(
                parts, ids, n_classes, cfg.vocab_size,
                local_steps=local_steps, batch=batch, seq_len=seq_len,
                profiles=profiles, seed=seed * 1000 + d)
            return {k: jnp.asarray(v) for k, v in batches_np.items()}

        source = PopulationSource(
            population, lambda ids: [specs[int(i)] for i in ids], batch_fn)
        acfg = AsyncConfig(
            capacity=capacity,
            merge_k=merge_k if merge_k > 0 else max(1, capacity // 2),
            staleness_max=staleness_max, deadline=async_deadline)
        params, _ = run_async(params, cfg, fl, rounds, source, key,
                              acfg=acfg, eval_every=eval_every,
                              eval_fn=record_eval, ckpt_path=ckpt,
                              mesh=mesh_obj)
    else:
        from repro.checkpoint import checkpoint as ckpt_mod
        from repro.core.round import eval_boundary
        for r in range(rounds):
            sel_specs, batches = round_data(r)
            params, loss = fl_round(params, cfg, fl, sel_specs, batches,
                                    jax.random.fold_in(key, r))
            if eval_boundary(r, rounds, eval_every):
                record_eval(r, float(loss), params)
                if ckpt is not None:
                    ckpt_mod.save(f"{ckpt}_r{r:05d}", params,
                                  meta={"round": r, "strategy": strategy})
    # rounds=0 (or eval_every configurations that never fire) leaves the
    # history empty — a scripted sweep no-op, not an IndexError
    hist["final_acc"] = hist["global_acc"][-1] if hist["global_acc"] else None
    hist["final_local_acc"] = hist["local_acc"][-1] if hist["local_acc"] else None
    return hist


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=["fl", "dense"], default="fl")
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--strategy", default="fedfa")
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--clients", type=int, default=16)
    ap.add_argument("--malicious-frac", type=float, default=0.0)
    ap.add_argument("--attack-lambda", type=float, default=1.0)
    ap.add_argument("--noniid", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--participation", type=float, default=0.5,
                    help="fraction C of clients selected per round")
    ap.add_argument("--local-steps", type=int, default=2,
                    help="E local SGD steps per round")
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--arch-mode", choices=["width", "depth", "both"],
                    default="width",
                    help="client flexibility regime (paper §5.1)")
    ap.add_argument("--task", choices=["cls", "lm"], default="cls")
    ap.add_argument("--eval-every", type=int, default=5,
                    help="<=0: evaluate on the final round only")
    ap.add_argument("--agg-engine", choices=["flat", "tree"], default="flat",
                    help="flat: the production engine; tree: slower "
                         "test-only differential oracle, kept for debugging")
    ap.add_argument("--driver", choices=["resident", "async", "per-round"],
                    default="resident",
                    help="resident: one jitted round program with donated "
                         "(N,)/(m,N) buffers; async: continuous-arrival "
                         "slot pool with bounded-staleness merges "
                         "(--rounds counts merges); per-round: re-dispatch "
                         "each round")
    ap.add_argument("--merge-k", type=int, default=0,
                    help="async: merge when this many updates arrived "
                         "(0 = half the pool capacity)")
    ap.add_argument("--staleness-max", type=int, default=4,
                    help="async: drop updates staler than this many "
                         "global versions")
    ap.add_argument("--async-deadline", type=float, default=float("inf"),
                    help="async: merge whatever arrived after this much "
                         "simulated time since the last merge")
    ap.add_argument("--mesh", choices=["none", "host", "production"],
                    default="none",
                    help="shard the resident round over the mesh: client "
                         "axis over data, (N,) parameter axis over model "
                         "(host: all local devices on data)")
    ap.add_argument("--mesh-shape", default=None, metavar="DxM",
                    help="explicit (data, model) mesh shape for the "
                         "resident round, e.g. 2x2 — D client shards x M "
                         "parameter shards; overrides --mesh")
    ap.add_argument("--use-kernel", choices=["auto", "on", "off"],
                    default="auto",
                    help="flat engine: Pallas kernel dispatch (auto=TPU only)")
    ap.add_argument("--interpret", action="store_true",
                    help="flat engine: run Pallas kernels in interpret mode")
    ap.add_argument("--update-dtype", choices=["f32", "bf16", "int8"],
                    default="f32",
                    help="cohort admission dtype (resident/async drivers): "
                         "int8/bf16 admit quantized rows with per-segment "
                         "scales + server-side error feedback; the fused "
                         "kernels dequantize in VMEM")
    ap.add_argument("--ckpt", default=None,
                    help="checkpoint path prefix (written at eval boundaries)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    if args.mode == "dense":
        res = run_dense(args.arch, args.steps, args.batch, args.seq_len)
    else:
        res = run_fl(args.arch, args.rounds, args.clients,
                     strategy=args.strategy,
                     malicious_frac=args.malicious_frac,
                     attack_lambda=args.attack_lambda, noniid=args.noniid,
                     batch=args.batch, seq_len=args.seq_len,
                     participation=args.participation,
                     local_steps=args.local_steps, lr=args.lr,
                     arch_mode=args.arch_mode, task=args.task,
                     eval_every=args.eval_every,
                     agg_engine=args.agg_engine, driver=args.driver,
                     merge_k=args.merge_k,
                     staleness_max=args.staleness_max,
                     async_deadline=args.async_deadline,
                     mesh=args.mesh_shape or args.mesh,
                     use_kernel={"auto": None, "on": True,
                                 "off": False}[args.use_kernel],
                     interpret=args.interpret,
                     update_dtype=args.update_dtype, ckpt=args.ckpt)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(res, f, indent=1)


if __name__ == "__main__":
    main()
