"""Analytic FLOP/byte model per (arch x input shape).

XLA's HloCostAnalysis counts while-loop bodies ONCE (verified empirically —
see EXPERIMENTS.md §Roofline methodology), so scan-based programs
under-report.  The roofline's compute term therefore comes from this exact
analytic model (matmul-level accounting, including the attention quadratic
term, MoE top-k routing, SSD chunk algebra), while memory/collective terms
come from per-layer HLO probes composed over the layer count.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

from repro.configs.base import ArchConfig, InputShape


def _attn_block_flops(cfg: ArchConfig, B: float, S: float,
                      kv_len: Optional[float] = None,
                      window: Optional[int] = None,
                      cross_len: float = 0.0) -> float:
    D, hd = cfg.d_model, cfg.head_dim
    H, K = cfg.n_heads, cfg.n_kv_heads
    proj = 2 * B * S * D * (H + 2 * K) * hd + 2 * B * S * H * hd * D
    if kv_len is None:                      # full self-attention over S
        eff = min(S, window) if window else S
        att_len = eff / 2 if (not window or S <= window) else eff
    else:                                   # decode against a cache
        att_len = min(kv_len, window) if window else kv_len
    attn = 2 * 2 * B * S * att_len * H * hd
    ffn = 0.0
    if cfg.moe:
        e = cfg.moe
        ffn += 2 * B * S * D * e.n_experts                      # router
        ffn += 2 * 3 * B * S * e.top_k * D * e.d_ff_expert      # experts
        if e.dense_residual:
            ffn += 2 * 3 * B * S * D * e.d_ff_expert
    else:
        ffn = 2 * 3 * B * S * D * cfg.d_ff
    x = 0.0
    if cross_len:
        x = (2 * B * S * D * (H + 2 * K) * hd + 2 * B * S * H * hd * D
             + 2 * 2 * B * S * cross_len * H * hd)
    return proj + attn + ffn + x


def _ssd_block_flops(cfg: ArchConfig, B: float, S: float) -> float:
    s = cfg.ssm
    D = cfg.d_model
    di = s.d_inner(D)
    nh, hp, N, Q = s.n_heads(D), s.head_dim, s.d_state, s.chunk
    proj = 2 * B * S * D * (2 * di + 2 * N + nh)
    conv = 2 * B * S * (di + 2 * N) * s.d_conv
    nc = max(S // Q, 1)
    intra = B * nc * nh * (2 * Q * Q * N + 2 * Q * Q * hp + 2 * Q * N * hp)
    inter = B * nc * nh * 2 * Q * N * hp
    out = 2 * B * S * di * D
    return proj + conv + intra + inter + out


def _rglru_block_flops(cfg: ArchConfig, B: float, S: float) -> float:
    D = cfg.d_model
    dr = cfg.rglru.d_rnn(D)
    proj = 2 * 2 * B * S * D * dr
    conv = 2 * B * S * dr * cfg.rglru.d_conv
    gates = 2 * 2 * B * S * dr * dr
    scan = 10 * B * S * dr                   # elementwise recurrence
    out = 2 * B * S * dr * D
    ffn = 2 * 3 * B * S * D * cfg.d_ff
    return proj + conv + gates + scan + out + ffn


def forward_flops(cfg: ArchConfig, shape: InputShape, *,
                  window: Optional[int] = None) -> float:
    """One forward pass (token-level) over the given shape."""
    B = shape.global_batch
    decode = shape.kind == "decode"
    S = 1.0 if decode else float(shape.seq_len)
    kv = float(shape.seq_len) if decode else None
    s_text = S
    total = 0.0
    cross = 0.0
    if cfg.vision is not None and not decode:
        s_text = S - cfg.vision.n_patches
        total += 2 * B * cfg.vision.n_patches * (
            cfg.vision.vit_dim * cfg.d_model + cfg.d_model * cfg.d_model)
    if cfg.encoder is not None:
        cross = cfg.encoder.n_frames
        if not decode:
            total += cfg.encoder.n_layers * _attn_block_flops(
                cfg, B, cross)               # encoder runs in prefill/train
    win = window if window is not None else cfg.attn_window
    for unit, reps in cfg.stages():
        for kind in unit:
            if kind == "attn":
                f = _attn_block_flops(cfg, B, S, kv_len=kv, window=win,
                                      cross_len=cross)
            elif kind == "ssd":
                f = _ssd_block_flops(cfg, B, S)
            else:
                f = _rglru_block_flops(cfg, B, S)
            total += f * reps
    total += 2 * B * S * cfg.d_model * cfg.padded_vocab  # lm head
    return total


def step_flops(cfg: ArchConfig, shape: InputShape, *,
               window: Optional[int] = None) -> float:
    f = forward_flops(cfg, shape, window=window)
    return 3.0 * f if shape.kind == "train" else f


def macs_per_client(cfg: ArchConfig, width_mult: float, section_depths,
                    B: int, S: int) -> float:
    """Paper Table 2 analog: MACs (= flops/2) for one client's local model
    forward+backward on one batch."""
    from repro.models.masks import width_spec
    sp = width_spec(cfg, width_mult)
    sub = cfg.replace(d_model=sp.d_model, n_heads=max(sp.n_heads, 1),
                      n_kv_heads=max(sp.n_kv_heads, 1),
                      d_ff=max(sp.d_ff, 1),
                      n_layers=max(int(sum(section_depths)
                                       * len(cfg.pattern_unit)), 1))
    shp = InputShape("local", S, B, "train")
    return step_flops(sub, shp) / 2.0
