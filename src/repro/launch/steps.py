"""Jittable production step functions: train / prefill / decode.

These are what the multi-pod dry-run lowers and what train.py / serve.py
drive.  Gradient accumulation (``cfg.grad_accum`` microbatches via
lax.scan) plus scan-over-layers remat keeps the large architectures inside
16 GB/chip HBM at train_4k.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, InputShape
from repro.models import model as model_mod
from repro.optim import init_opt, opt_update, make_schedule
from repro.sharding import hints

Params = Dict[str, Any]


def make_train_step(cfg: ArchConfig, total_steps: int = 1000):
    sched = make_schedule(cfg.schedule, cfg.learning_rate, total_steps,
                          warmup=max(total_steps // 100, 1))

    def train_step(params, opt_state, batch, step):
        A = cfg.grad_accum

        def gradfn(p, mb):
            (loss, aux), g = jax.value_and_grad(
                model_mod.loss_fn, has_aux=True)(p, cfg, mb, task="lm")
            return g, loss

        lr = sched(step)
        if A == 1:
            grads, loss = gradfn(params, batch)
            params, opt_state = opt_update(cfg.optimizer, params, grads,
                                           opt_state, lr)
            return params, opt_state, loss

        micro = jax.tree.map(
            lambda x: x.reshape((A, x.shape[0] // A) + x.shape[1:]), batch)

        if cfg.optimizer == "sgd":
            # Fused momentum accumulation (§Perf iter 2, confirmed): the
            # microbatch grads accumulate DIRECTLY into the momentum buffer
            # (m' = mu*m + mean_i g_i + wd*p), eliminating the separate
            # fp32 grad-accumulator tree — 7.3 GB/chip for arctic-480b,
            # the difference between fitting 16 GB HBM and not.
            def body(carry, mb):
                m_acc, l_acc = carry
                g, l = gradfn(params, mb)
                m_acc = jax.tree.map(lambda m, gg: m + gg / A, m_acc, g)
                return (m_acc, l_acc + l), None

            m0 = jax.tree.map(
                lambda m, p: cfg.momentum * m.astype(jnp.float32)
                + cfg.weight_decay * p.astype(jnp.float32),
                opt_state["m"], params)
            (m_new, lsum), _ = jax.lax.scan(body, (m0, jnp.zeros(())), micro)
            params = jax.tree.map(
                lambda p, m: (p.astype(jnp.float32) - lr * m).astype(p.dtype),
                params, m_new)
            mdt = jnp.bfloat16 if cfg.momentum_dtype == "bfloat16" else jnp.float32
            m_new = jax.tree.map(lambda m: m.astype(mdt), m_new)
            opt_state = {"step": opt_state["step"] + 1, "m": m_new}
            return params, opt_state, lsum / A

        def body(carry, mb):
            g_acc, l_acc = carry
            g, l = gradfn(params, mb)
            return (jax.tree.map(jnp.add, g_acc, g), l_acc + l), None

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (grads, lsum), _ = jax.lax.scan(body, (g0, jnp.zeros(())), micro)
        grads = jax.tree.map(lambda g: g / A, grads)
        loss = lsum / A
        params, opt_state = opt_update(cfg.optimizer, params, grads, opt_state, lr)
        return params, opt_state, loss

    return train_step


def make_prefill_step(cfg: ArchConfig, *, window: Optional[int] = None,
                      masks=None):
    def prefill_step(params, batch):
        logits, caches, enc_out = model_mod.prefill(
            params, cfg, batch, window=window, masks=masks,
            capacity=_prefill_capacity(cfg, batch),
            chunk_size=cfg.prefill_chunk)
        out = (logits, caches)
        if cfg.encoder is not None:
            out = out + (enc_out,)
        return out
    return prefill_step


def _prefill_capacity(cfg, batch) -> int:
    cap = batch["tokens"].shape[1]
    if cfg.vision is not None:
        cap += cfg.vision.n_patches
    return cap


def make_decode_step(cfg: ArchConfig, *, window: Optional[int] = None,
                     masks=None):
    def decode_step(params, caches, token, enc_out=None):
        logits, caches = model_mod.decode_step(
            params, cfg, token, caches, window=window, enc_out=enc_out,
            masks=masks)
        return logits, caches
    return decode_step


# ---------------------------------------------------------------------------
# Dry-run input specs (ShapeDtypeStruct stand-ins; no allocation)
# ---------------------------------------------------------------------------

def sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ArchConfig, shape: InputShape, *,
                window: Optional[int] = None) -> Dict[str, Any]:
    """Model inputs for one (arch x input-shape) combination."""
    B, S = shape.global_batch, shape.seq_len
    dt = jnp.int32
    if shape.kind == "train" or shape.kind == "prefill":
        s_text = S
        batch: Dict[str, Any] = {}
        if cfg.vision is not None:
            s_text = S - cfg.vision.n_patches
            batch["patches"] = sds((B, cfg.vision.n_patches, cfg.vision.vit_dim),
                                   jnp.bfloat16)
        if cfg.encoder is not None:
            batch["frames"] = sds((B, cfg.encoder.n_frames, cfg.d_model),
                                  jnp.bfloat16)
        batch["tokens"] = sds((B, s_text), dt)
        return batch
    # decode: one token + capacity-S caches
    batch = {"tokens": sds((B, 1), dt)}
    return batch


def decode_cache_specs(cfg: ArchConfig, shape: InputShape, *,
                       window: Optional[int] = None):
    """Abstract caches for decode dry-runs (already 'prefilled' shapes)."""
    B, S = shape.global_batch, shape.seq_len
    fn = functools.partial(model_mod.init_caches, None, cfg, B, S,
                           window=window, dtype=jnp.bfloat16)
    return jax.eval_shape(fn)
