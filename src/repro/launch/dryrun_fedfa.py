import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")
"""Dry-run of the paper's core workload: one full FedFA round (16
heterogeneous clients, local SGD, layer grafting + scalable aggregation)
lowered for the 16x16 production mesh with the client axis sharded over
``data`` — the server *is* the pod.

python -m repro.launch.dryrun_fedfa [--arch smollm-135m] [--clients 16]
"""
import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_arch
from repro.core.server import ClientSpec, FLConfig, fl_round
from repro.launch.dryrun import collective_bytes
from repro.launch.mesh import make_production_mesh
from repro.models import model as model_mod
from repro.models.masks import ClientArch, max_section_depths


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--clients", type=int, default=16)
    ap.add_argument("--local-steps", type=int, default=4)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq-len", type=int, default=512)
    ap.add_argument("--agg-engine", choices=["flat", "tree"], default="flat")
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()

    cfg = get_arch(args.arch).replace(grad_accum=1)
    maxd = max_section_depths(cfg)
    pool = [ClientArch(w, tuple(max(1, int(np.ceil(f * m))) for m in maxd))
            for w, f in [(0.25, 0.5), (0.5, 0.75), (0.75, 1.0), (1.0, 1.0)]]
    specs = [ClientSpec(arch=pool[i % len(pool)], n_data=100 + i)
             for i in range(args.clients)]
    fl = FLConfig(local_steps=args.local_steps, lr=0.05, strategy="fedfa",
                  task="lm", agg_engine=args.agg_engine)
    mesh = make_production_mesh()

    params_abs = jax.eval_shape(
        lambda: model_mod.init_params(cfg, jax.random.PRNGKey(0), jnp.bfloat16))
    batch_abs = {"tokens": jax.ShapeDtypeStruct(
        (args.clients, args.local_steps, args.batch, args.seq_len), jnp.int32)}
    key_abs = jax.ShapeDtypeStruct((2,), jnp.uint32)

    def round_fn(gp, batches, key):
        return fl_round(gp, cfg, fl, specs, batches, key,
                        any_malicious=False)

    t0 = time.time()
    with mesh:
        jitted = jax.jit(
            round_fn,
            in_shardings=(None,                       # global model replicated
                          {"tokens": NamedSharding(mesh, P("data"))},
                          NamedSharding(mesh, P())),
            out_shardings=(None, None))
        lowered = jitted.lower(
            params_abs, batch_abs,
            jax.random.PRNGKey(0))
        compiled = lowered.compile()
    rec = dict(arch=args.arch, workload="fedfa_round", mesh="16x16",
               clients=args.clients, agg_engine=args.agg_engine,
               lower_compile_s=round(time.time() - t0, 1))
    ma = compiled.memory_analysis()
    rec["memory"] = dict(argument_bytes=ma.argument_size_in_bytes,
                         temp_bytes=ma.temp_size_in_bytes,
                         peak_bytes=ma.argument_size_in_bytes
                         + ma.temp_size_in_bytes)
    ca = compiled.cost_analysis()
    rec["cost"] = {k: ca.get(k) for k in ("flops", "bytes accessed") if k in ca}
    rec["collectives"] = collective_bytes(compiled.as_text())
    rec["status"] = "ok"
    path = os.path.join(args.out, f"fedfa_round_{args.arch}_16x16.json")
    os.makedirs(args.out, exist_ok=True)
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    print(f"fedfa_round[{args.arch} x{args.clients} clients]: "
          f"compile {rec['lower_compile_s']}s, "
          f"peak {rec['memory']['peak_bytes']/2**30:.2f} GB/dev, "
          f"collectives {rec['collectives']['total']/2**20:.1f} MB/dev")


if __name__ == "__main__":
    main()
