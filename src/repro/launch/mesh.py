"""Production mesh construction (TPU v5e target).

Defined as functions (never module-level constants) so importing this module
never touches jax device state — smoke tests must keep seeing 1 CPU device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Single-device mesh for CPU tests of the sharded code paths."""
    return jax.make_mesh((1, 1), ("data", "model"))


def make_data_mesh(n_data=None):
    """All local devices on the ``data`` axis — the sharded resident round's
    mesh on CPU hosts (use ``XLA_FLAGS=--xla_force_host_platform_device_count=K``
    to test multi-shard lowering without accelerators)."""
    n = jax.device_count() if n_data is None else n_data
    return jax.make_mesh((n, 1), ("data", "model"))


def get_mesh(name):
    """CLI-level mesh selection: ``none`` | ``host`` | ``production``.

    ``host`` puts every local device on the data axis (degenerates to the
    1x1 host mesh on a single-device CPU); ``production`` is the TPU v5e
    pod mesh above.
    """
    if name is None or name == "none":
        return None
    if name == "host":
        return make_data_mesh()
    if name == "production":
        return make_production_mesh()
    raise ValueError(f"unknown mesh {name!r} (none|host|production)")
