"""Production mesh construction (TPU v5e target).

Defined as functions (never module-level constants) so importing this module
never touches jax device state — smoke tests must keep seeing 1 CPU device.

Every constructor validates the requested shape against the visible device
count and raises a ValueError naming both, instead of surfacing
``jax.make_mesh``'s opaque reshape failure.
"""
from __future__ import annotations

import re
from typing import Tuple

import jax


def _validated_mesh(shape, axes):
    need = 1
    for s in shape:
        need *= int(s)
    have = jax.device_count()
    if need > have:
        raise ValueError(
            f"mesh shape {tuple(shape)} over axes {tuple(axes)} needs {need} "
            f"devices but only {have} are visible "
            f"(jax.device_count() == {have}); pick a shape whose product is "
            f"<= {have} or launch with more devices "
            f"(XLA_FLAGS=--xla_force_host_platform_device_count=K on CPU)")
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _validated_mesh(shape, axes)


def make_host_mesh():
    """Single-device mesh for CPU tests of the sharded code paths."""
    return _validated_mesh((1, 1), ("data", "model"))


def make_data_mesh(n_data=None):
    """All local devices on the ``data`` axis — the 1-D sharded resident
    round's mesh on CPU hosts (use
    ``XLA_FLAGS=--xla_force_host_platform_device_count=K`` to test
    multi-shard lowering without accelerators)."""
    n = jax.device_count() if n_data is None else n_data
    return _validated_mesh((n, 1), ("data", "model"))


def parse_mesh_shape(s: str) -> Tuple[int, int]:
    """``"DxM"`` -> (n_data, n_model), e.g. ``"2x2"`` -> (2, 2)."""
    m = re.fullmatch(r"(\d+)x(\d+)", s.strip().lower())
    if not m or int(m.group(1)) < 1 or int(m.group(2)) < 1:
        raise ValueError(f"mesh shape {s!r} is not of the form DxM "
                         f"(positive ints, e.g. 2x2)")
    return int(m.group(1)), int(m.group(2))


def make_mesh_2d(n_data: int, n_model: int):
    """Explicit (data, model) mesh — n_data client shards x n_model
    parameter shards (see ``repro.sharding.cohort``)."""
    return _validated_mesh((n_data, n_model), ("data", "model"))


def get_mesh(name):
    """CLI-level mesh selection: ``none`` | ``host`` | ``production`` | an
    explicit ``DxM`` shape (e.g. ``2x2``).

    ``host`` puts every local device on the data axis (degenerates to the
    1x1 host mesh on a single-device CPU); ``production`` is the TPU v5e
    pod mesh above; ``DxM`` builds a real 2-D (data, model) mesh — D client
    shards x M parameter shards.
    """
    if name is None or name == "none":
        return None
    if name == "host":
        return make_data_mesh()
    if name == "production":
        return make_production_mesh()
    if re.fullmatch(r"\d+x\d+", str(name).strip().lower()):
        return make_mesh_2d(*parse_mesh_shape(name))
    raise ValueError(f"unknown mesh {name!r} (none|host|production|DxM)")
