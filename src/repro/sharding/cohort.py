"""Cohort-axis sharding for the resident FL round.

The resident round (``repro.core.round.flat_round``) is an SPMD reduction
over the client cohort: every argument with a leading client axis m — the
(m, N) cohort buffer, stacked width masks / depth gates / graft maps, data
counts, class masks, malicious flags and the stacked local batches — is
partitioned over the mesh ``data`` axis, while the (N,) global buffer (and
the PRNG key) stay replicated.  Local training then runs data-parallel over
client shards and the fused (M', γ) reductions lower to per-shard partial
sums plus one ``psum`` (see ``repro.kernels.fedfa_agg.ops.accumulate``).
The trimmed-norm pass — including the fused Pallas trimmed-quantile kernel
(``repro.kernels.fedfa_quantile``) — is per-(client, segment) work with no
collectives, so it runs entirely inside each shard of the same shard_map.

Uneven cohorts (m % n_data_shards != 0) are handled host-side by padding
the cohort with inert rows: ``n_data = 0`` zeroes a pad row's weight in
both accumulated sums (the γ = 0 keep-global rule already covers segments
nobody updates) and the round program averages the reported loss over the
real rows only.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

DATA_AXIS = "data"


def data_shards(mesh: Optional[Mesh]) -> int:
    """Number of shards of the client axis (1 without a mesh)."""
    if mesh is None:
        return 1
    return int(mesh.shape[DATA_AXIS])


def shardable(mesh: Optional[Mesh], m: int) -> bool:
    """Can a client axis of length m be shard_map'ed over this mesh?
    (mesh present, has the data axis, and divides m — padded cohorts always
    qualify; callers fall back to the unsharded body otherwise)."""
    return (mesh is not None and DATA_AXIS in mesh.axis_names
            and m % data_shards(mesh) == 0)


def cohort_sharding(mesh: Mesh) -> NamedSharding:
    """Leading client axis over ``data``, everything else replicated.

    A PartitionSpec shorter than the array rank leaves trailing dims
    replicated, so one sharding covers every cohort-stacked leaf — the
    (m, N) buffer, (m,) counts/flags, (m, R) gates, (m, E, B, S) batches —
    and works as a pytree prefix for whole argument subtrees.
    """
    return NamedSharding(mesh, P(DATA_AXIS))


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


def round_shardings(mesh: Mesh) -> Tuple[Tuple, Tuple]:
    """(in_shardings, out_shardings) for the resident round program

      (g_buf, c_buf, masks, gates, gmaps, nd, cms, mal, batches, key)
        -> (g_buf', x, loss)

    matching ``repro.core.round.make_flat_round``: cohort-stacked arguments
    sharded over ``data``, the global buffer / key / loss replicated.  The
    donated pairs keep matching shardings (g_buf -> g_buf' replicated,
    c_buf -> x cohort-sharded) so XLA can still alias their buffers.
    """
    co, rep = cohort_sharding(mesh), replicated(mesh)
    return ((rep, co, co, co, co, co, co, co, co, rep), (rep, co, rep))


def constrain_cohort(x: jax.Array, mesh: Optional[Mesh]) -> jax.Array:
    """Pin a client-stacked intermediate to the cohort sharding.

    Applied to the (m, N) tensors inside ``flat.aggregate_buffers`` so
    GSPMD keeps the per-client elementwise work sharded instead of
    resolving the reduction operands to a replicated gather.
    """
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(x, cohort_sharding(mesh))


def pad_rows(m: int, mesh: Optional[Mesh]) -> int:
    """Pad rows needed to make the cohort divisible by the data shards."""
    return (-m) % data_shards(mesh)


def _pad_leading(tree: Any, pad: int) -> Any:
    """Append ``pad`` copies of row 0 along every leaf's leading axis (row
    content is arbitrary for pad rows — repeating a real row keeps shapes,
    dtypes and mask semantics valid)."""
    return jax.tree.map(
        lambda a: jnp.concatenate(
            [a, jnp.broadcast_to(a[:1], (pad,) + a.shape[1:])]), tree)


def pad_cohort(runtimes: Tuple, batches: Any, pad: int) -> Tuple[Tuple, Any]:
    """Pad the ``server.stack_runtimes`` tuple + stacked batches with inert
    rows: masks/gates/gmaps/class-masks/batches repeat row 0, ``n_data`` is
    0 (zero weight in both (M', γ) sums) and ``malicious`` is 0.
    """
    if pad <= 0:
        return runtimes, batches
    masks, gates, gmaps, nd, cms, mal = runtimes
    zeros = jnp.zeros((pad,), jnp.float32)
    padded = (_pad_leading(masks, pad), _pad_leading(gates, pad),
              _pad_leading(gmaps, pad), jnp.concatenate([nd, zeros]),
              None if cms is None else _pad_leading(cms, pad),
              jnp.concatenate([mal, zeros]))
    return padded, _pad_leading(batches, pad)
