"""2-D ``(data, model)`` sharding for the resident FL round.

The resident round (``repro.core.round.flat_round``) is an SPMD reduction
over the client cohort, laid out over a 2-D mesh:

  * **client axis m over ``data``** — every argument with a leading client
    axis (the (m, N) cohort buffer, stacked width masks / depth gates /
    graft maps, data counts, class masks, malicious flags and the stacked
    local batches) is partitioned over the mesh ``data`` axis.  Local
    training runs data-parallel over client shards.
  * **parameter axis N over ``model``** — the two *resident* N-sized
    buffers, the (N,) global model and the donated (m, N) cohort scratch,
    keep only an N/n_model slice per device between rounds
    (``global_sharding`` = P("model"), ``cohort_buffer_sharding`` =
    P("data", "model")), FSDP-style.

Inside the round the N axis now splits *early*: the trimmed-norm pass
consumes P("data", "model") slices directly via the two-stage distributed
quantile (``kernels.fedfa_quantile.multilevel`` — per-level histograms
``psum``'d over ``model``, never the rows), so densities, norms and both
fused (M', γ) reductions all run on each device's (m/D, N/n_model) slice:
the reductions are per-shard partial sums finished by one N/n_model-sized
``psum`` over ``data`` (no reduce-scatter needed — the N axis is pre-split;
see ``repro.kernels.fedfa_agg.ops.accumulate``).  The only step still
touching whole rows is the graft gather (a data-dependent cross-shard row
permutation), which runs in a transient model-replicated window
(``cohort_sharding`` = P("data")) bounded by the round contract's
re-layout caps; with grafting off — or pre-grafted rows, as in the async
slot pool — the round is 2-D end-to-end.  The (M'/Γ, γ = 0) merge runs
per-shard on the N/n_model slices.  The aggregation path therefore lowers
with ZERO all-gathers and per-device all-reduce volume ~N/n_model plus
histogram-sized quantile planes; the only all-gather in the whole round is
the unavoidable global-model broadcast into local training.

Uneven cohorts (m % n_data_shards != 0) are handled host-side by padding
the cohort with inert rows: ``n_data = 0`` zeroes a pad row's weight in
both accumulated sums (the γ = 0 keep-global rule already covers segments
nobody updates) and the round program averages the reported loss over the
real rows only.  The parameter axis pads the same way: ``flat.FlatIndex``
rounds N up to a multiple of the model-shard count with an inert
zero-density tail segment (offsets stay static; pads never enter norms, α
or the merged global — see ``flat.FlatIndex``).
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

DATA_AXIS = "data"
MODEL_AXIS = "model"


def data_shards(mesh: Optional[Mesh]) -> int:
    """Number of shards of the client axis (1 without a mesh)."""
    if mesh is None:
        return 1
    return int(mesh.shape[DATA_AXIS])


def model_shards(mesh: Optional[Mesh]) -> int:
    """Number of shards of the (N,) parameter axis (1 without a mesh or
    without a ``model`` mesh axis)."""
    if mesh is None or MODEL_AXIS not in mesh.axis_names:
        return 1
    return int(mesh.shape[MODEL_AXIS])


def pad_unit(mesh: Optional[Mesh]) -> int:
    """``FlatIndex(pad_to=)`` for this mesh: the model-shard count, widened
    to a multiple of the two-stage quantile kernel's column tile when the
    model axis is real, so each shard's local slice of the N axis tiles the
    distributed norms pass evenly — the kernel consumes the slice with no
    staging pad copy, keeping the pass literally read-once."""
    ms = model_shards(mesh)
    if ms <= 1:
        return 1
    from repro.kernels.fedfa_quantile.multilevel import TILE
    return ms * TILE


def shardable(mesh: Optional[Mesh], m: int) -> bool:
    """Can a client axis of length m be shard_map'ed over this mesh?
    (mesh present, has the data axis, and divides m — padded cohorts always
    qualify; callers fall back to the unsharded body otherwise)."""
    return (mesh is not None and DATA_AXIS in mesh.axis_names
            and m % data_shards(mesh) == 0)


def cohort_sharding(mesh: Mesh) -> NamedSharding:
    """Leading client axis over ``data``, everything else replicated.

    A PartitionSpec shorter than the array rank leaves trailing dims
    replicated, so one sharding covers every cohort-stacked leaf — the
    (m, N) buffer, (m,) counts/flags, (m, R) gates, (m, E, B, S) batches —
    and works as a pytree prefix for whole argument subtrees.
    """
    return NamedSharding(mesh, P(DATA_AXIS))


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


def global_sharding(mesh: Mesh) -> NamedSharding:
    """The resident (N,) global buffer: sharded over ``model`` (replicated
    when the mesh has no model shards, so data-only meshes keep PR 3's
    layout bit-for-bit)."""
    if model_shards(mesh) == 1:
        return replicated(mesh)
    return NamedSharding(mesh, P(MODEL_AXIS))


def cohort_buffer_sharding(mesh: Mesh) -> NamedSharding:
    """The resident donated (m, N) cohort buffer: clients over ``data`` AND
    the parameter axis over ``model`` — the between-rounds layout.  Since
    the distributed two-stage quantile landed, the aggregation consumes
    this 2-D layout directly (the norms pass psums per-level histograms
    over ``model`` instead of reading whole rows); only the graft gather
    still opens a transient model-replicated window."""
    if model_shards(mesh) == 1:
        return cohort_sharding(mesh)
    return NamedSharding(mesh, P(DATA_AXIS, MODEL_AXIS))


def round_shardings(mesh: Mesh) -> Tuple[Tuple, Tuple]:
    """(in_shardings, out_shardings) for the resident round program

      (g_buf, c_buf, masks, gates, gmaps, nd, cms, mal, batches, keys)
        -> (g_buf', x, loss)

    matching ``repro.core.round.make_flat_round``: cohort-stacked arguments
    (including the host-split per-client keys) sharded over ``data``, the
    (N,) global buffer over ``model``, the donated (m, N) scratch over
    ``(data, model)``, loss replicated.  The donated pairs keep matching
    in/out shardings (g_buf -> g_buf', c_buf -> x) so XLA can still alias
    their buffers.
    """
    co, rep = cohort_sharding(mesh), replicated(mesh)
    gl, cb = global_sharding(mesh), cohort_buffer_sharding(mesh)
    return ((gl, cb, co, co, co, co, co, co, co, co), (gl, cb, rep))


def quantized_round_shardings(mesh: Mesh) -> Tuple[Tuple, Tuple]:
    """(in_shardings, out_shardings) for the QUANTIZED resident round

      (g_buf, c_buf, s_buf, e_buf, es_buf, masks, gates, gmaps, nd, cms,
       mal, batches, keys) -> (g_buf', x_q, scales, e_q, e_s, loss)

    (``repro.core.round.make_flat_round`` with ``update_dtype`` != f32).
    The int8/bf16 cohort pool and the error-feedback pool keep the
    resident 2-D ``cohort_buffer_sharding`` layout; the small (m, S)
    scale tables shard over ``data`` like every cohort-stacked argument.
    All five donated pairs keep matching in/out shardings so XLA aliases
    them (g_buf -> g_buf', c_buf -> x_q, s_buf -> scales, e_buf -> e_q,
    es_buf -> e_s)."""
    co, rep = cohort_sharding(mesh), replicated(mesh)
    gl, cb = global_sharding(mesh), cohort_buffer_sharding(mesh)
    return ((gl, cb, co, cb, co, co, co, co, co, co, co, co, co),
            (gl, cb, co, cb, co, rep))


def quantized_admit_shardings(mesh: Mesh) -> Tuple[Tuple, Tuple]:
    """(in_shardings, out_shardings) for the QUANTIZED async admit program

      (g_buf, c_buf, s_buf, e_buf, es_buf, masks, gates, gmaps, cms, mal,
       batches, keys, written) -> (c_buf', s_buf', e_buf', es_buf', losses)

    (``repro.core.async_round.make_admit_program`` with a quantized
    admission dtype): the layout story of ``async_admit_shardings`` with
    the pool split into quantized rows + scales + error-feedback
    residuals, every pool donated to its same-sharded output."""
    co, gl = cohort_sharding(mesh), global_sharding(mesh)
    cb = cohort_buffer_sharding(mesh)
    return ((gl, cb, co, cb, co, co, co, co, co, co, co, co, co),
            (cb, co, cb, co, co))


def quantized_merge_shardings(mesh: Mesh) -> Tuple[Tuple, Tuple]:
    """(in_shardings, out_shardings) for the QUANTIZED async merge program

      (g_buf, c_buf, s_buf, masks, gates, gmaps, w) -> g_buf'

    — ``async_merge_shardings`` plus the (m, S) scale table over ``data``;
    the quantized pool is consumed in its resident 2-D layout by the
    fused dequantize-aggregate."""
    co, gl = cohort_sharding(mesh), global_sharding(mesh)
    cb = cohort_buffer_sharding(mesh)
    return ((gl, cb, co, co, co, co, co), gl)


def async_admit_shardings(mesh: Mesh) -> Tuple[Tuple, Tuple]:
    """(in_shardings, out_shardings) for the async engine's admit program

      (g_buf, c_buf, masks, gates, gmaps, cms, mal, batches, keys, written)
        -> (c_buf', losses)

    (``repro.core.async_round.make_admit_program``).  The slot-pool c_buf
    lives in the resident 2-D P("data", "model") ``cohort_buffer_sharding``
    layout END-TO-END between programs: the distributed two-stage quantile
    lets the merge's trimmed-norm pass consume N/n_model slices directly,
    and the admit grafts rows at admission time (the trained rows are still
    naturally model-replicated whole rows there, so the graft gather is
    shard-local) before slicing them into the pool.  Each device's resident
    pool bytes drop by the model-shard factor — the PR 6 follow-up (a) the
    ROADMAP carried.  Every stacked argument — including the (rows,)
    ``written`` row mask — arrives in slot order and shards over ``data``
    like the resident round, so the admit select is elementwise per shard
    and the program still lowers with zero collectives (``admit_contract``).
    """
    co, gl = cohort_sharding(mesh), global_sharding(mesh)
    cb = cohort_buffer_sharding(mesh)
    return ((gl, cb, co, co, co, co, co, co, co, co), (cb, co))


def async_merge_shardings(mesh: Mesh) -> Tuple[Tuple, Tuple]:
    """(in_shardings, out_shardings) for the async engine's merge program

      (g_buf, c_buf, masks, gates, gmaps, w) -> g_buf'

    (``repro.core.async_round.make_merge_program``).  The slot pool arrives
    in the resident 2-D P("data", "model") layout and the aggregation
    consumes it there directly: rows were grafted at admit, so the merge is
    2-D end-to-end — per-shard partial sums, histogram-sized quantile
    psums over ``model`` and one N/n_model psum over ``data``, zero
    all-gathers and zero re-layout collectives.  g_buf keeps the resident
    P("model") layout on both sides so XLA aliases the donated pair.
    """
    co, gl = cohort_sharding(mesh), global_sharding(mesh)
    cb = cohort_buffer_sharding(mesh)
    return ((gl, cb, co, co, co, co), gl)


def constrain_cohort(x: jax.Array, mesh: Optional[Mesh]) -> jax.Array:
    """Pin a client-stacked intermediate to the cohort sharding.

    Applied to the (m, N) tensors inside ``flat.aggregate_buffers`` so
    GSPMD keeps the per-client elementwise work sharded instead of
    resolving the reduction operands to a replicated gather.
    """
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(x, cohort_sharding(mesh))


def constrain_cohort_buffer(x: jax.Array, mesh: Optional[Mesh]) -> jax.Array:
    """Pin the round's returned (m, N) cohort buffer to the resident 2-D
    layout (clients over ``data``, N over ``model``).  Coming from the
    model-replicated ``cohort_sharding`` layout this is a local slice —
    each device drops the N-slices it no longer owns, no collectives."""
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(x, cohort_buffer_sharding(mesh))


def pad_rows(m: int, mesh: Optional[Mesh]) -> int:
    """Pad rows needed to make the cohort divisible by the data shards."""
    return (-m) % data_shards(mesh)


def _pad_leading(tree: Any, pad: int) -> Any:
    """Append ``pad`` copies of row 0 along every leaf's leading axis (row
    content is arbitrary for pad rows — repeating a real row keeps shapes,
    dtypes and mask semantics valid)."""
    return jax.tree.map(
        lambda a: jnp.concatenate(
            [a, jnp.broadcast_to(a[:1], (pad,) + a.shape[1:])]), tree)


def pad_cohort(runtimes: Tuple, batches: Any, pad: int) -> Tuple[Tuple, Any]:
    """Pad the ``server.stack_runtimes`` tuple + stacked batches with inert
    rows: masks/gates/gmaps/class-masks/batches repeat row 0, ``n_data`` is
    0 (zero weight in both (M', γ) sums) and ``malicious`` is 0.
    """
    if pad <= 0:
        return runtimes, batches
    masks, gates, gmaps, nd, cms, mal = runtimes
    zeros = jnp.zeros((pad,), jnp.float32)
    padded = (_pad_leading(masks, pad), _pad_leading(gates, pad),
              _pad_leading(gmaps, pad), jnp.concatenate([nd, zeros]),
              None if cms is None else _pad_leading(cms, pad),
              jnp.concatenate([mal, zeros]))
    return padded, _pad_leading(batches, pad)
