"""Activation-sharding hints.

Model code calls ``constrain(x, kind)`` at layout-critical points; by
default this is a no-op (CPU tests), and the launcher installs a policy
mapping kinds -> PartitionSpecs before lowering for the production mesh.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional

import jax
from jax.sharding import PartitionSpec as P

_state = threading.local()


def current_policy() -> Optional[Dict[str, P]]:
    return getattr(_state, "policy", None)


@contextlib.contextmanager
def policy(mapping: Dict[str, P]):
    prev = current_policy()
    _state.policy = mapping
    try:
        yield
    finally:
        _state.policy = prev


def constrain(x: jax.Array, kind: str) -> jax.Array:
    pol = current_policy()
    if pol is None or kind not in pol:
        return x
    spec = pol[kind]
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec)


def megatron_policy(batch_axes=("data",), model_axis="model") -> Dict[str, P]:
    """Residual replicated over model; heads/ffn/experts sharded over model."""
    b = batch_axes if len(batch_axes) > 1 else batch_axes[0]
    return {
        "residual": P(b, None, None),
        "heads": P(b, None, model_axis, None),
        "ffn": P(b, None, model_axis),
        "experts": P(model_axis, None, None),
        "tokens": P(b, None),
        "logits": P(b, None, model_axis),
    }
