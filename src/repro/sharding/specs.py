"""Parameter / input PartitionSpec policy for the production mesh.

Megatron-style tensor parallel over ``model`` (flattened head dims, d_ff,
vocab, experts, d_rnn/d_inner) plus FSDP over ``data`` for archs flagged
``fsdp=True``.  GSPMD handles non-divisible dims (e.g. vocab=122753 on 16
shards) by internal padding; the honest FLOP cost of that padding shows up
in the roofline's useful-FLOPs ratio.

Specs are built as a tree parallel to ``init_params`` (same technique as
``repro.core.masking.axis_mask_tree``); depth-stacked stage leaves get a
leading ``None`` for the repeat axis.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig

Params = Dict[str, Any]


def batch_axes(multi_pod: bool) -> Tuple[str, ...]:
    return ("pod", "data") if multi_pod else ("data",)


def _pre(spec: P, lead: int = 1) -> P:
    return P(*([None] * lead + list(spec)))


def _norm_spec(cfg, lead=0) -> Dict[str, P]:
    s = {"scale": _pre(P(None), lead)}
    if cfg.norm == "layernorm":
        s["bias"] = _pre(P(None), lead)
    return s


def _attn_spec(cfg, f, lead=1) -> Dict[str, P]:
    return {"wq": _pre(P(f, "model"), lead), "wk": _pre(P(f, "model"), lead),
            "wv": _pre(P(f, "model"), lead), "wo": _pre(P("model", f), lead)}


def _ffn_spec(cfg, f, lead=1) -> Dict[str, P]:
    if cfg.norm == "layernorm":
        return {"w_in": _pre(P(f, "model"), lead), "b_in": _pre(P("model"), lead),
                "w_out": _pre(P("model", f), lead), "b_out": _pre(P(None), lead)}
    return {"w_gate": _pre(P(f, "model"), lead), "w_up": _pre(P(f, "model"), lead),
            "w_down": _pre(P("model", f), lead)}


def _moe_spec(cfg, f, lead=1) -> Dict[str, P]:
    s = {"router": _pre(P(f, None), lead),
         "w_gate": _pre(P("model", f, None), lead),
         "w_up": _pre(P("model", f, None), lead),
         "w_down": _pre(P("model", None, f), lead)}
    if cfg.moe.dense_residual:
        s["dense"] = {k: v for k, v in _ffn_spec(cfg, f, lead).items()}
    return s


def _ssd_spec(cfg, f, lead=1) -> Dict[str, P]:
    return {"in_proj": _pre(P(f, "model"), lead),
            "conv_w": _pre(P(None, "model"), lead),
            "conv_b": _pre(P("model"), lead),
            "A_log": _pre(P(None), lead), "D": _pre(P(None), lead),
            "dt_bias": _pre(P(None), lead),
            "norm": _pre(P("model"), lead),
            "out_proj": _pre(P("model", f), lead)}


def _rglru_spec(cfg, f, lead=1) -> Dict[str, P]:
    return {"in_x": _pre(P(f, "model"), lead), "in_gate": _pre(P(f, "model"), lead),
            "conv_w": _pre(P(None, "model"), lead), "conv_b": _pre(P("model"), lead),
            "w_r": _pre(P(None, "model"), lead), "b_r": _pre(P("model"), lead),
            "w_i": _pre(P(None, "model"), lead), "b_i": _pre(P("model"), lead),
            "lam": _pre(P("model"), lead),
            "out": _pre(P("model", f), lead)}


def _block_spec(kind: str, cfg: ArchConfig, f, cross: bool, lead=1) -> Dict[str, Any]:
    if kind == "attn":
        s = {"ln1": _norm_spec(cfg, lead), "attn": _attn_spec(cfg, f, lead),
             "ln2": _norm_spec(cfg, lead),
             "ffn": _moe_spec(cfg, f, lead) if cfg.moe else _ffn_spec(cfg, f, lead)}
        if cross:
            s["lnx"] = _norm_spec(cfg, lead)
            s["xattn"] = _attn_spec(cfg, f, lead)
        return s
    if kind == "ssd":
        return {"ln": _norm_spec(cfg, lead), "ssd": _ssd_spec(cfg, f, lead)}
    if kind == "rglru":
        return {"ln1": _norm_spec(cfg, lead), "rg": _rglru_spec(cfg, f, lead),
                "ln2": _norm_spec(cfg, lead), "ffn": _ffn_spec(cfg, f, lead)}
    raise ValueError(kind)


def param_specs(cfg: ArchConfig, *, fsdp: Optional[bool] = None,
                multi_pod: bool = False) -> Params:
    """PartitionSpec tree matching init_params(cfg).  With multi_pod, FSDP
    shards over BOTH batch axes ('pod','data') — otherwise each pod holds a
    full optimizer replica and the second pod buys no memory (measured:
    §Perf iter 2)."""
    want = cfg.fsdp if fsdp is None else fsdp
    f = (("pod", "data") if multi_pod else "data") if want else None
    cross = cfg.encoder is not None
    t: Params = {"embed": P("model", f)}
    stages = []
    for unit, reps in cfg.stages():
        stages.append(tuple(_block_spec(k, cfg, f, cross) for k in unit))
    t["stages"] = tuple(stages)
    t["final_norm"] = _norm_spec(cfg)
    if not cfg.tie_embeddings:
        t["lm_head"] = P(f, "model")
    if cfg.rope_theta <= 0.0:
        t["pos_embed"] = P(None, f)
    if cfg.vision is not None:
        t["projector"] = {"w1": P(None, f), "w2": P(f, None)}
    if cfg.encoder is not None:
        t["encoder"] = {"blocks": _block_spec("attn", cfg, f, cross=False),
                        "final_norm": _norm_spec(cfg)}
    return t


def opt_state_specs(cfg: ArchConfig, pspecs: Params, has_v: bool) -> Params:
    st = {"step": P(), "m": pspecs}
    if has_v:
        st["v"] = pspecs
    return st


def cache_specs(cfg: ArchConfig, multi_pod: bool) -> Params:
    """Spec tree matching model.init_caches output (stacked per stage).
    Built with the cache NamedTuples themselves so pytree structures match."""
    from repro.models.attention import KVCache
    from repro.models.rglru import RGLRUCache
    from repro.models.ssm import SSMCache
    b = batch_axes(multi_pod)
    bspec = b if len(b) > 1 else b[0]
    kv_model = "model" if cfg.n_kv_heads >= 8 else None
    out = []
    for unit, reps in cfg.stages():
        stage = []
        for kind in unit:
            if kind == "attn":
                kv = P(None, bspec, None, kv_model, None)
                stage.append({"self": KVCache(k=kv, v=kv, pos=P(None))})
            elif kind == "ssd":
                stage.append({"ssm": SSMCache(
                    conv=P(None, bspec, None, "model"),
                    h=P(None, bspec, None, None, None),
                    pos=P(None))})
            elif kind == "rglru":
                stage.append({"rg": RGLRUCache(
                    conv=P(None, bspec, None, "model"),
                    h=P(None, bspec, "model"),
                    pos=P(None))})
        out.append(tuple(stage))
    return tuple(out)


def sanitize_specs(spec_tree, abstract_tree, mesh):
    """Drop sharding on any dim the mesh axes don't divide.

    jax.jit's explicit in/out shardings require exact divisibility (unlike
    internal GSPMD propagation); non-divisible dims (odd vocabs, kv_heads=8
    on a 16-way model axis, batch=1 long-context decode) fall back to
    replication.  A spec naming an axis the mesh doesn't have (e.g. a
    ("pod", "data") FSDP spec sanitized against the 2-axis single-pod mesh)
    is likewise treated as non-divisible and replicated.  Each fallback is
    an honest memory/roofline cost visible in the dry-run — padding configs
    away is a §Perf iteration, not a default.
    """
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def fix(spec, aval):
        if not isinstance(spec, P):
            return spec
        shape = aval.shape
        entries = list(spec) + [None] * (len(shape) - len(spec))
        out = []
        for dim, ent in zip(shape, entries):
            if ent is None:
                out.append(None)
                continue
            axes = ent if isinstance(ent, tuple) else (ent,)
            total = 1
            for a in axes:
                if a not in sizes:      # axis absent from this mesh
                    total = 0
                    break
                total *= sizes[a]
            out.append(ent if total and dim % total == 0 else None)
        return P(*out)

    return jax.tree.map(fix, spec_tree, abstract_tree,
                        is_leaf=lambda x: isinstance(x, P))


def batch_specs(cfg: ArchConfig, multi_pod: bool, kind: str) -> Dict[str, P]:
    b = batch_axes(multi_pod)
    bspec = b if len(b) > 1 else b[0]
    s = {"tokens": P(bspec, None)}
    if kind == "train":
        pass
    if cfg.vision is not None:
        s["patches"] = P(bspec, None, None)
    if cfg.encoder is not None:
        s["frames"] = P(bspec, None, None)
    return s
