"""HLO collective-op inspection for the sharding gates.

The sharded round's invariants (zero all-gathers in the aggregation path,
reduce-scattered (M', γ) sums, per-device all-reduce volume ~N/n_model) are
asserted by walking compiled HLO text in ``benchmarks/bench_shard.py`` and
``tests/_force_multidevice_child.py``.  This module is the ONE copy of that
walk, so the parsing rules — count the ``-start(`` half of async pairs
(which carries the shape), never the ``-done(`` half; take the first shape
on the line — stay in lockstep everywhere the invariant is gated.
"""
from __future__ import annotations

import re
from typing import List, Optional, Tuple

KINDS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
         "collective-permute")

_SHAPE_RE = re.compile(r"=\s*\(?([a-z0-9]+)\[([\d,]*)\]")


def result_elems(line: str) -> Optional[int]:
    """Element count of the first shape on an HLO line (None if shapeless)."""
    sm = _SHAPE_RE.search(line)
    if sm is None:
        return None
    e = 1
    for d in (int(d) for d in sm.group(2).split(",") if d):
        e *= d
    return e


def collective_lines(txt: str) -> List[Tuple[str, Optional[int]]]:
    """All collective ops of a compiled-HLO text as (kind, result elems).

    Sync ops lower as `` all-reduce(...)``; TPU/GPU backends often emit
    async pairs — the ``-start(`` half (which carries the shape) is counted,
    never the ``-done(`` half, so each op appears exactly once.
    """
    out = []
    for line in txt.splitlines():
        for kind in KINDS:
            if f" {kind}(" in line or f" {kind}-start(" in line:
                out.append((kind, result_elems(line)))
    return out


def count(txt: str, kind: str) -> int:
    return sum(1 for k, _ in collective_lines(txt) if k == kind)


def sizes(txt: str, kind: str, min_elems: int = 0) -> List[int]:
    """Result sizes of every ``kind`` op with >= min_elems elements."""
    return [e for k, e in collective_lines(txt)
            if k == kind and e is not None and e >= min_elems]
