"""Back-compat shim: the HLO collective walk moved to ``repro.analysis.hlo``.

The structured analyzer (typed ``CollectiveOp`` records, tuple-shaped
async ``-start`` results, layout annotations, donation aliases) is the ONE
copy of the HLO parsing rules; import ``repro.analysis.hlo`` directly in
new code.  This module re-exports the legacy surface so existing callers
keep working.
"""
from __future__ import annotations

from repro.analysis.hlo import (KINDS, CollectiveOp,  # noqa: F401
                                collective_lines, collectives, count,
                                max_elems, result_elems, sizes)
