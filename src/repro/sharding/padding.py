"""Head padding for serving — FedFA's padded-dense machinery reused as the
production sharding-padding mechanism.

Architectures whose kv-head count doesn't divide the 16-way model axis
(minicpm 36, smollm 3, tinyllama 4, recurrentgemma 1) otherwise REPLICATE
their KV cache across the model axis: minicpm decode_32k costs 270 GB/device
and a 180 GB all-gather (EXPERIMENTS.md §Perf iteration 1).  Padding kv
heads to a multiple of 16 and masking the extras with a FedFA width mask is
*exactly* a width-masked client model, so correctness is already proven by
the width-equivalence tests.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.masks import WidthMasks, full_masks


def pad_heads_for_serving(cfg: ArchConfig, axis: int = 16
                          ) -> Tuple[ArchConfig, Optional[WidthMasks]]:
    """Returns (padded config, width masks activating only the real heads).

    No-op (masks=None) when kv heads already divide the model axis or the
    architecture is attention-free.
    """
    K = cfg.n_kv_heads
    if K == 0 or K % axis == 0:
        return cfg, None
    group = cfg.n_heads // K
    Kp = (K + axis - 1) // axis * axis
    cfg2 = cfg.replace(n_kv_heads=Kp, n_heads=Kp * group)
    m = full_masks(cfg2)
    masks = dataclasses.replace(
        m,
        heads=(jnp.arange(cfg2.n_heads) < cfg.n_heads).astype(jnp.float32),
        kv_heads=(jnp.arange(Kp) < K).astype(jnp.float32))
    return cfg2, masks
