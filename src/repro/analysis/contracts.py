"""Declarative program contracts checked against lowered/compiled programs.

A ``Contract`` states the *structural* invariants a compiled round program
must satisfy — zero all-gathers on the aggregation path, reduce-scattered
(M', γ) sums with per-device all-reduce volume <= N/n_model, donation
aliases materialized, the fused quantile reading each cohort row exactly
once — as data, not as ad-hoc asserts.  Programs declare their contract
next to their builder (``core/round.py::round_contract``,
``core/async_round.py::admit_contract``/``merge_contract``,
``kernels/fedfa_agg/ops.py::accumulate_contract``,
``kernels/fedfa_quantile/ops.py::fused_quantile_contract``), and every
gate site — benchmarks, the forced-multidevice test child, and
``python -m repro.analysis check`` — evaluates the same objects.

Count-valued fields take a ``Bound``: an exact int, a ``(lo, hi)`` tuple
(either end None for open), or None for unchecked.  HLO fields are
measured on ``compiled.as_text()`` via ``repro.analysis.hlo``; jaxpr
fields on a traced jaxpr via ``repro.analysis.jaxpr``; ``donated`` on the
compiled module's ``input_output_alias`` header.

This module is dependency-light on purpose (stdlib + the sibling
``hlo``/``jaxpr`` modules, no jax import at module scope): the program
modules in ``repro.core`` and ``repro.kernels`` import it at module load
to declare their contracts.
"""
from __future__ import annotations

from dataclasses import dataclass, fields
from typing import Dict, List, Optional, Sequence, Tuple, Union

from repro.analysis import hlo as hlo_mod

Bound = Union[int, Tuple[Optional[int], Optional[int]], None]


def check_bound(name: str, value: int, bound: Bound) -> Optional[str]:
    """Violation message (or None) for ``value`` against ``bound``."""
    if bound is None:
        return None
    if isinstance(bound, int):
        if value != bound:
            return f"{name} == {value}, expected exactly {bound}"
        return None
    lo, hi = bound
    if lo is not None and value < lo:
        return f"{name} == {value}, expected >= {lo}"
    if hi is not None and value > hi:
        return f"{name} == {value}, expected <= {hi}"
    return None


def _fmt_bound(bound: Bound) -> str:
    if isinstance(bound, int):
        return f"=={bound}"
    lo, hi = bound
    if lo is None:
        return f"<={hi}"
    if hi is None:
        return f">={lo}"
    return f"in[{lo},{hi}]"


@dataclass(frozen=True)
class Contract:
    """Structural contract of one compiled/traced program.

    HLO collective structure (measured on ``compiled.as_text()``):
      all_gathers / reduce_scatters / all_to_alls / collective_permutes
                       Bound on the op count (async pairs count once).
      allreduce_max_elems
                       No all-reduce payload may exceed this many elements
                       (the per-device-volume cap: N/n_model with model
                       shards, N on a data-only mesh).
      scale_allreduces / scale_elems
                       Bound on the number of all-reduces of EXACTLY
                       ``scale_elems`` elements — the (M', γ) partial-sum
                       reductions.  Independent of the cap so a program
                       with uncapped training-side all-reduces can still
                       pin its aggregation psum count.
      full_cohort_gathers / cohort_elems
                       Bound on all-gathers whose payload >= cohort_elems
                       (materializing the full (m, N) cohort is the
                       failure the sharded round exists to prevent).
      max_all_gather_elems
                       Largest tolerated all-gather payload (e.g. the <= N
                       global-model broadcast into local training).
      peak_live_bytes_per_device
                       Bound on the statically-estimated per-device peak
                       live bytes (``analysis/memory`` live-interval sweep
                       over the scheduled module; the partitioned text is
                       already per-device).  Proves donation ping-pong
                       does not double-buffer and the cohort scratch stays
                       ~(m, N)/(D*M) bytes per device.

    Donation (measured on the ``input_output_alias`` header):
      donated          Parameter indices that must have materialized
                       aliases — the resident ping-pong buffers.

    Traced-program structure (measured on a jaxpr + ``row_elems``):
      row_reads        Bound on compute ops consuming the row block.
      sorts            Bound on sort/top_k ops.
    """
    name: str
    description: str = ""
    all_gathers: Bound = None
    reduce_scatters: Bound = None
    all_to_alls: Bound = None
    collective_permutes: Bound = None
    allreduce_max_elems: Optional[int] = None
    scale_allreduces: Bound = None
    scale_elems: Optional[int] = None
    full_cohort_gathers: Bound = None
    cohort_elems: Optional[int] = None
    max_all_gather_elems: Optional[int] = None
    peak_live_bytes_per_device: Bound = None
    donated: Optional[frozenset] = None
    row_reads: Bound = None
    sorts: Bound = None

    def __post_init__(self):
        if self.full_cohort_gathers is not None and self.cohort_elems is None:
            raise ValueError(
                f"contract {self.name!r}: full_cohort_gathers needs "
                f"cohort_elems (the full-cohort payload size)")
        if self.scale_allreduces is not None and self.scale_elems is None:
            raise ValueError(
                f"contract {self.name!r}: scale_allreduces needs "
                f"scale_elems (the payload size it counts)")

    # -- evaluation --------------------------------------------------------

    def _needs_hlo(self) -> bool:
        return any(getattr(self, f.name) is not None for f in fields(self)
                   if f.name in ("all_gathers", "reduce_scatters",
                                 "all_to_alls", "collective_permutes",
                                 "allreduce_max_elems", "scale_allreduces",
                                 "full_cohort_gathers",
                                 "max_all_gather_elems",
                                 "peak_live_bytes_per_device", "donated"))

    _SPEC_SKIP = ("name", "description", "cohort_elems", "scale_elems")

    def _needs_jaxpr(self) -> bool:
        return self.row_reads is not None or self.sorts is not None

    def check(self, *, hlo: Optional[str] = None, jaxpr=None,
              row_elems: Optional[int] = None) -> "Report":
        """Evaluate the contract against a compiled-HLO text and/or a
        traced jaxpr; returns a ``Report`` (ok + measured + violations)."""
        measured: Dict[str, object] = {}
        violations: List[str] = []

        if self._needs_hlo():
            if hlo is None:
                violations.append("contract has HLO fields but no compiled "
                                  "HLO text was provided")
            else:
                self._check_hlo(hlo, measured, violations)
        if self._needs_jaxpr():
            if jaxpr is None:
                violations.append("contract has jaxpr fields but no jaxpr "
                                  "was provided")
            else:
                self._check_jaxpr(jaxpr, row_elems, measured, violations)
        if self.donated is not None and hlo is not None:
            donated = set(hlo_mod.donated_params(hlo))
            measured["donated"] = sorted(donated)
            missing = set(self.donated) - donated
            if missing:
                violations.append(
                    f"donation aliases missing for parameter(s) "
                    f"{sorted(missing)} (materialized: {sorted(donated)})")
        blame_rows = None
        if hlo is not None:
            from repro.analysis import blame as blame_mod
            blame_rows = blame_mod.blame_table(hlo)
        return Report(contract=self, measured=measured,
                      violations=violations, blame=blame_rows)

    @staticmethod
    def _with_blame(msg: str, ops, kinds) -> str:
        """Append source attributions for the offending collective kinds —
        every collective-structure failure names the Python line to fix."""
        from repro.analysis import blame as blame_mod
        lines = blame_mod.format_blame(ops, kinds=list(kinds), limit=4)
        if lines:
            msg += "".join("\n      blame: " + ln for ln in lines)
        return msg

    def _check_hlo(self, txt: str, measured, violations) -> None:
        ops = hlo_mod.collectives(txt)
        counters = (("all_gathers", "all-gather"),
                    ("reduce_scatters", "reduce-scatter"),
                    ("all_to_alls", "all-to-all"),
                    ("collective_permutes", "collective-permute"))
        for field, kind in counters:
            n = hlo_mod.count(ops, kind)
            measured[field] = n
            v = check_bound(field, n, getattr(self, field))
            if v:
                violations.append(self._with_blame(v, ops, (kind,)))
        ar_sizes = hlo_mod.sizes(ops, "all-reduce")
        measured["all_reduces"] = len(ar_sizes)
        if self.allreduce_max_elems is not None:
            big = [e for e in ar_sizes if e > self.allreduce_max_elems]
            measured["allreduce_max_elems"] = max(ar_sizes, default=0)
            if big:
                violations.append(self._with_blame(
                    f"all-reduce payload(s) {big} exceed "
                    f"{self.allreduce_max_elems} elems",
                    ops, ("all-reduce",)))
        if self.scale_allreduces is not None:
            n_scale = sum(1 for e in ar_sizes if e == self.scale_elems)
            measured["scale_allreduces"] = n_scale
            v = check_bound("scale_allreduces", n_scale,
                            self.scale_allreduces)
            if v:
                violations.append(self._with_blame(v, ops, ("all-reduce",)))
        ag_max = hlo_mod.max_elems(ops, "all-gather")
        measured["max_all_gather_elems"] = ag_max
        if self.max_all_gather_elems is not None \
                and ag_max > self.max_all_gather_elems:
            violations.append(self._with_blame(
                f"all-gather of {ag_max} elems exceeds "
                f"{self.max_all_gather_elems}", ops, ("all-gather",)))
        if self.full_cohort_gathers is not None:
            n_full = len(hlo_mod.sizes(ops, "all-gather",
                                       min_elems=self.cohort_elems))
            measured["full_cohort_gathers"] = n_full
            v = check_bound("full_cohort_gathers", n_full,
                            self.full_cohort_gathers)
            if v:
                violations.append(self._with_blame(v, ops, ("all-gather",)))
        if self.peak_live_bytes_per_device is not None:
            from repro.analysis import memory as memory_mod
            est = memory_mod.analyze(txt)
            measured["peak_live_bytes_per_device"] = est.peak_bytes
            v = check_bound("peak_live_bytes_per_device", est.peak_bytes,
                            self.peak_live_bytes_per_device)
            if v:
                top = ", ".join(f"{name}={b}B" for name, b in est.top[:3])
                violations.append(
                    f"{v} (peak at schedule idx {est.peak_index}; "
                    f"largest live buffers: {top})")

    def _check_jaxpr(self, jaxpr, row_elems, measured, violations) -> None:
        from repro.analysis import jaxpr as jaxpr_mod
        if self.row_reads is not None and row_elems is None:
            violations.append("contract has row_reads but no row_elems "
                              "was provided")
            return
        c = jaxpr_mod.walk(jaxpr, row_elems=row_elems)
        measured["row_reads"] = c.reads
        measured["sorts"] = c.sorts
        for field, val in (("row_reads", c.reads), ("sorts", c.sorts)):
            v = check_bound(field, val, getattr(self, field))
            if v:
                violations.append(v)

    def spec(self) -> str:
        """Compact one-line rendering of the declared bounds."""
        parts = []
        for f in fields(self):
            if f.name in self._SPEC_SKIP:
                continue
            val = getattr(self, f.name)
            if val is None:
                continue
            if f.name == "donated":
                parts.append(f"donated={sorted(val)}")
            elif f.name in ("allreduce_max_elems", "max_all_gather_elems"):
                parts.append(f"{f.name}<={val}")
            else:
                parts.append(f"{f.name}{_fmt_bound(val)}")
        return " ".join(parts)


@dataclass
class Report:
    """One contract evaluation: measured values + violations + (when HLO
    text was provided) the per-provenance collective blame table."""
    contract: Contract
    measured: Dict[str, object]
    violations: List[str]
    blame: Optional[List] = None  # List[blame.BlameEntry]

    @property
    def ok(self) -> bool:
        return not self.violations

    def to_json(self) -> Dict[str, object]:
        """JSON-serializable dict (for ``check --json`` / ANALYSIS.json):
        the declared spec, every measured value, violations and the
        per-provenance blame table."""
        from dataclasses import asdict
        return {
            "program": self.contract.name,
            "description": self.contract.description,
            "spec": self.contract.spec(),
            "measured": dict(self.measured),
            "violations": list(self.violations),
            "ok": self.ok,
            "blame": [asdict(b) for b in self.blame or []],
        }


def format_table(reports: Sequence[Report]) -> str:
    """The one-table rendering ``python -m repro.analysis check`` prints:
    program | declared contract | measured | PASS/FAIL (+ violations)."""
    rows = [("program", "contract", "measured", "status")]
    for r in reports:
        meas = " ".join(f"{k}={v}" for k, v in sorted(r.measured.items()))
        rows.append((r.contract.name, r.contract.spec(), meas,
                     "PASS" if r.ok else "FAIL"))
    widths = [max(len(row[i]) for row in rows) for i in range(4)]
    lines = []
    for i, row in enumerate(rows):
        lines.append("  ".join(c.ljust(w) for c, w in zip(row, widths)))
        if i == 0:
            lines.append("  ".join("-" * w for w in widths))
    for r in reports:
        for v in r.violations:
            lines.append(f"FAIL {r.contract.name}: {v}")
    return "\n".join(lines)
