"""Structured HLO analysis: collective ops + donation aliases of a compiled
program.

This is the ONE copy of the HLO text-parsing rules (it absorbed — and PR 8
finally deleted — the former ``repro.sharding.collectives`` shim): every gate
that inspects a lowered round —
``benchmarks/bench_shard.py``, ``bench_quantile.py``, ``bench_async.py``,
``tests/_force_multidevice_child.py`` and the ``repro.analysis`` contract
checker — goes through the typed records here, so the parsing conventions
stay in lockstep everywhere the invariants are asserted.

Parsing rules (see also ``repro/analysis/README.md``):

  * An instruction line is ``%name = <result-shape> <op>(...)``.  Only the
    canonical collective op names in ``KINDS`` are recognized, and the op
    name must be immediately followed by ``(`` so ``metadata={op_name=...}``
    strings and fusion-computation names never false-positive.
  * **Async pairs**: TPU/GPU backends lower collectives as
    ``<op>-start`` / ``<op>-done`` pairs.  The ``-start`` half carries the
    shape and is recorded (``is_async=True``); the ``-done`` half is
    skipped, so each op appears exactly once whether it lowered sync or
    async.
  * **Tuple-shaped results**: an async start may return a tuple — e.g.
    ``(f32[1024]{0}, u32[])`` (payload + sync flag) or, for all-gather on
    TPU, ``(f32[256], f32[1024])`` (operand, result).  The payload element
    count is the MAX element count over the tuple's floating-point shapes
    (falling back to max over all shapes when no float is present).  For
    the gated kinds this never under-counts: all-reduce result == operand,
    all-gather result >= operand.  Layout annotations (``{1,0:T(256)}``)
    and an optional leading tuple are handled.
  * ``replica_groups={{0,1},{2,3}}`` / iota ``[2,2]<=[4]`` forms are kept
    verbatim on the record for replica-group-sensitive checks.
  * ``metadata={op_name="..." source_file="..." source_line=N}`` is parsed
    onto the record so ``analysis/blame.py`` can attribute each collective
    to the Python line that introduced it.  Every field is optional — XLA
    drops metadata on ops it synthesizes itself (e.g. the resharding half
    of an all-to-all pair), and those stay ``None``.

Donation: the compiled module header carries
``input_output_alias={ {out}: (param, {index}, kind) }`` — ``donated_params``
parses it so contracts can assert the resident ping-pong buffers actually
aliased (a silently-dropped donation doubles resident memory without
changing results, which no numeric test catches).
"""
from __future__ import annotations

import re
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple, Union

KINDS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
         "collective-permute")

_FLOAT_DTYPES = ("f64", "f32", "f16", "bf16", "f8e5m2", "f8e4m3fn")

# dtype[dims]{optional layout} — dims empty for scalars (e.g. ``u32[]``)
_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([\d,]*)\](?:\{[^}]*\})?")

# ``= <result> <op>(``: result is a single shape or a tuple of shapes.
# Tile annotations nest parens inside the layout braces ({1,0:T(256)}),
# so the tuple branch must allow parens there while still stopping at the
# tuple's own closing paren.
_INSTR_RE = re.compile(
    r"=\s*(?P<result>\((?:[^(){}]|\{[^{}]*\})*\)"
    r"|[a-z][a-z0-9]*\[[\d,]*\](?:\{[^}]*\})?)\s+"
    r"(?P<op>[a-z][a-z0-9-]*)\(")

_REPLICA_RE = re.compile(r"replica_groups=(\{\{[\d,{}\s]*\}\}|\[[\d,]*\]<=\[\d+\])")

_METADATA_RE = re.compile(r"metadata=\{([^{}]*)\}")
_MD_FIELD_RE = re.compile(r'(\w+)=(?:"((?:[^"\\]|\\.)*)"|(\d+))')

# content nests braces one level deep ({out-index} and {param-index} tuples)
_ALIAS_HDR_RE = re.compile(
    r"input_output_alias=\{((?:[^{}]|\{[^{}]*\})*)\}")
_ALIAS_ENTRY_RE = re.compile(
    r"\{[\d,\s]*\}:\s*\(\s*(\d+)\s*,\s*\{[^}]*\}\s*,\s*(may-alias|must-alias)\)")


@dataclass(frozen=True)
class CollectiveOp:
    """One collective op of a compiled program.

    kind            canonical name (``all-reduce``, never ``-start``)
    elems           payload element count (None if the line had no shape)
    shapes          every (dtype, dims) of the result, tuple-flattened
    is_async        lowered as a ``-start``/``-done`` pair
    replica_groups  the verbatim ``replica_groups=`` value (None if absent)
    line_no         1-based line in the HLO text (for error messages)
    op_name         HLO ``metadata={op_name=...}`` (None if absent)
    source_file     HLO ``metadata={source_file=...}`` (None if absent)
    source_line     HLO ``metadata={source_line=...}`` (None if absent)
    """
    kind: str
    elems: Optional[int]
    shapes: Tuple[Tuple[str, Tuple[int, ...]], ...]
    is_async: bool
    replica_groups: Optional[str]
    line_no: int
    op_name: Optional[str] = None
    source_file: Optional[str] = None
    source_line: Optional[int] = None


def _elems(dims: Tuple[int, ...]) -> int:
    e = 1
    for d in dims:
        e *= d
    return e


def parse_shapes(text: str) -> Tuple[Tuple[str, Tuple[int, ...]], ...]:
    """Every ``dtype[dims]`` shape token of an HLO fragment (layout
    annotations stripped), as ((dtype, dims), ...)."""
    return tuple(
        (m.group(1), tuple(int(d) for d in m.group(2).split(",") if d))
        for m in _SHAPE_RE.finditer(text))


def payload_elems(shapes: Sequence[Tuple[str, Tuple[int, ...]]]
                  ) -> Optional[int]:
    """The collective's real payload element count from its (possibly
    tuple-shaped) result: max over floating-point shapes, else max over all
    shapes — never the blindly-first shape on the line (an async start's
    ``u32[]`` sync flag, or a layout-annotated operand, may come first)."""
    if not shapes:
        return None
    floats = [_elems(dims) for dt, dims in shapes if dt in _FLOAT_DTYPES]
    if floats:
        return max(floats)
    return max(_elems(dims) for _, dims in shapes)


def result_elems(line: str) -> Optional[int]:
    """Payload element count of one HLO instruction line (None if
    shapeless).  Tuple-shaped and layout-annotated results are handled —
    the shapes are taken from the result (between ``=`` and the op name)
    when the line parses as an instruction, else from the whole line."""
    m = _INSTR_RE.search(line)
    frag = m.group("result") if m else line
    return payload_elems(parse_shapes(frag))


def parse_metadata(line: str) -> Dict[str, Union[str, int]]:
    """The ``metadata={...}`` fields of one HLO instruction line as a dict
    (``source_line`` and other bare-integer fields become ints).  Empty when
    the line carries no metadata — XLA omits it on ops it synthesizes."""
    m = _METADATA_RE.search(line)
    if m is None:
        return {}
    out: Dict[str, Union[str, int]] = {}
    for key, sval, ival in _MD_FIELD_RE.findall(m.group(1)):
        out[key] = int(ival) if ival else sval
    return out


def collectives(txt: str, strict: bool = False) -> List[CollectiveOp]:
    """All collective ops of a compiled-HLO text, in program order.

    Counts each op exactly once: sync ``<kind>(`` lines and async
    ``<kind>-start(`` lines are recorded; ``-done`` halves are skipped.
    With ``strict``, an unbalanced start/done count raises ValueError.
    """
    out: List[CollectiveOp] = []
    starts: Dict[str, int] = {}
    dones: Dict[str, int] = {}
    for ln, line in enumerate(txt.splitlines(), start=1):
        m = _INSTR_RE.search(line)
        if m is None:
            continue
        op = m.group("op")
        kind, is_async = op, False
        if op.endswith("-start"):
            kind, is_async = op[:-len("-start")], True
        elif op.endswith("-done"):
            base = op[:-len("-done")]
            if base in KINDS:
                dones[base] = dones.get(base, 0) + 1
            continue
        if kind not in KINDS:
            continue
        if is_async:
            starts[kind] = starts.get(kind, 0) + 1
        shapes = parse_shapes(m.group("result"))
        rg = _REPLICA_RE.search(line)
        md = parse_metadata(line)
        sl = md.get("source_line")
        out.append(CollectiveOp(kind=kind, elems=payload_elems(shapes),
                                shapes=shapes, is_async=is_async,
                                replica_groups=rg.group(1) if rg else None,
                                line_no=ln,
                                op_name=md.get("op_name"),
                                source_file=md.get("source_file"),
                                source_line=sl if isinstance(sl, int) else None))
    if strict and starts != dones:
        raise ValueError(
            f"unbalanced async collective pairs: starts={starts} "
            f"dones={dones}")
    return out


Source = Union[str, Sequence[CollectiveOp]]


def _ops(src: Source) -> Sequence[CollectiveOp]:
    return collectives(src) if isinstance(src, str) else src


def collective_lines(txt: str) -> List[Tuple[str, Optional[int]]]:
    """Back-compat view: [(kind, payload elems), ...]."""
    return [(op.kind, op.elems) for op in collectives(txt)]


def count(src: Source, kind: str) -> int:
    """Number of ``kind`` collectives in an HLO text (or parsed op list)."""
    return sum(1 for op in _ops(src) if op.kind == kind)


def sizes(src: Source, kind: str, min_elems: int = 0) -> List[int]:
    """Payload sizes of every ``kind`` op with >= min_elems elements."""
    return [op.elems for op in _ops(src)
            if op.kind == kind and op.elems is not None
            and op.elems >= min_elems]


def max_elems(src: Source, kind: str) -> int:
    """Largest payload of any ``kind`` op (0 if none)."""
    return max((op.elems for op in _ops(src)
                if op.kind == kind and op.elems is not None), default=0)


_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}


def result_bytes(op: CollectiveOp) -> int:
    """Total result bytes of one collective (every shape of a tuple result,
    unknown dtypes skipped) — the interconnect-traffic proxy the dry-run
    roofline divides by ICI bandwidth."""
    return sum(_elems(dims) * _DTYPE_BYTES[dt] for dt, dims in op.shapes
               if dt in _DTYPE_BYTES)


def byte_totals(src: Source) -> Dict[str, int]:
    """{kind: summed result bytes} over every collective, plus ``total``."""
    out: Dict[str, int] = {}
    for op in _ops(src):
        out[op.kind] = out.get(op.kind, 0) + result_bytes(op)
    out["total"] = sum(out.values())
    return out


def summarize(src: Source) -> Dict[str, int]:
    """{kind: count} over every collective kind present."""
    out: Dict[str, int] = {}
    for op in _ops(src):
        out[op.kind] = out.get(op.kind, 0) + 1
    return out


def donated_params(txt: str) -> Dict[int, str]:
    """{parameter number: alias kind} from the compiled module's
    ``input_output_alias`` header — the donations XLA actually
    materialized.  Empty when nothing aliased (donation silently dropped,
    or none requested)."""
    m = _ALIAS_HDR_RE.search(txt)
    if m is None:
        return {}
    return {int(p): kind for p, kind in _ALIAS_ENTRY_RE.findall(m.group(1))}
