"""CLI: ``python -m repro.analysis {check,lint}``.

check   Lower + compile the canonical program set (sync round on data-only
        and 2x2 meshes, standalone aggregation, async admit + merge, fused
        quantile) and print every declared contract in one table, plus the
        cache/donation passes.  Forces 4 host devices via a subprocess
        re-exec when the host has fewer (XLA reads
        ``--xla_force_host_platform_device_count`` at jax init, so it
        cannot be set in-process).  Exit 1 on any FAIL.  ``--json PATH``
        additionally writes the machine-readable report (measured values,
        violations, peak estimates, blame tables) to PATH — the flag
        rides through the re-exec, so the forced-device child writes it.

lint    Run the FL-specific AST lints (``repro.analysis.lint``) over the
        given paths (default ``src/``).  Exit 1 on any finding.
"""
from __future__ import annotations

import argparse
import os
import subprocess
import sys

_CHILD_ENV = "_REPRO_ANALYSIS_CHILD"
_FORCE_FLAG = "--xla_force_host_platform_device_count=4"


def _reexec_with_devices(argv) -> int:
    env = dict(os.environ)
    env[_CHILD_ENV] = "1"
    env["XLA_FLAGS"] = f"{env.get('XLA_FLAGS', '')} {_FORCE_FLAG}".strip()
    return subprocess.call([sys.executable, "-m", "repro.analysis"] + argv,
                           env=env)


def _cmd_check(args) -> int:
    import jax
    if jax.device_count() < 4:
        if os.environ.get(_CHILD_ENV):
            print(f"ERROR: forced-device child still sees only "
                  f"{jax.device_count()} device(s)", file=sys.stderr)
            return 2
        return _reexec_with_devices(sys.argv[1:])

    from repro.analysis import format_table
    from repro.analysis import programs

    progress = (lambda s: print(s, flush=True)) if not args.quiet \
        else (lambda s: None)
    reports = programs.canonical_reports(progress)
    print()
    print(format_table(reports))
    ok = all(r.ok for r in reports)

    print()
    passes = []
    for name, violations in programs.cache_checks():
        status = "PASS" if not violations else "FAIL"
        passes.append({"name": name, "ok": not violations,
                       "violations": list(violations)})
        print(f"{status}  {name}")
        for v in violations:
            print(f"      {v}")
            ok = False
    print()
    n_fail = sum(1 for r in reports if not r.ok)
    print(f"contracts: {len(reports) - n_fail}/{len(reports)} passed"
          + ("" if ok else "  [FAIL]"))
    if args.json:
        import json
        payload = {
            "ok": ok,
            "programs": [r.to_json() for r in reports],
            "passes": passes,
        }
        os.makedirs(os.path.dirname(os.path.abspath(args.json)),
                    exist_ok=True)
        with open(args.json, "w") as fh:
            json.dump(payload, fh, indent=2, sort_keys=True)
            fh.write("\n")
        print(f"wrote {args.json}")
    return 0 if ok else 1


def _cmd_lint(args) -> int:
    from repro.analysis import lint

    findings = lint.lint_paths(args.paths)
    for f in findings:
        print(f)
    print(f"{len(findings)} finding(s) over {len(args.paths)} path(s)")
    return 1 if findings else 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.analysis",
                                 description=__doc__)
    sub = ap.add_subparsers(dest="cmd", required=True)
    ck = sub.add_parser("check", help="lower the canonical program set "
                                      "and report every contract")
    ck.add_argument("--quiet", action="store_true",
                    help="suppress per-program progress lines")
    ck.add_argument("--json", metavar="PATH", default=None,
                    help="also write the full machine-readable report "
                         "(per-program measured values, violations, peak "
                         "estimates, blame tables) to PATH")
    ck.set_defaults(fn=_cmd_check)
    ln = sub.add_parser("lint", help="run the FL-specific source lints")
    ln.add_argument("paths", nargs="*", default=["src/"],
                    help="files/directories to lint (default: src/)")
    ln.set_defaults(fn=_cmd_lint)
    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
