"""Static peak-live-bytes estimate of a scheduled HLO module.

CPU/TPU modules compiled by XLA carry ``is_scheduled=true``: the instruction
order inside each computation IS the buffer-assignment schedule, so a classic
live-interval sweep over that order gives a static per-device peak — the
module text of an SPMD-partitioned program is already the *per-device*
program (shard-local shapes), which is what makes the estimate a per-device
bound rather than a global one.

Model (see ``README.md`` for the over/under-approximation discussion):

  * Every instruction whose result is a fresh buffer contributes its result
    bytes from its schedule position to its last use.  Tuple results count
    the sum of their element shapes.
  * **View ops** allocate nothing and forward liveness to their operands:
    ``tuple`` / ``get-tuple-element`` / ``bitcast`` /
    ``optimization-barrier``, any async ``*-done`` half, and — key for the
    resident ping-pong — ``while``, whose carried buffers XLA updates in
    place.  A use of a view is a use of every underlying allocation.
  * **Parameters** are caller-owned and counted live for the whole program
    (JAX keeps input buffers alive across the call; an early last-use frees
    nothing on the device).
  * **Donation** (``input_output_alias`` header): an aliased output reuses
    its parameter's buffer, so the allocation backing that ROOT element is
    collapsed to zero bytes.  This is the static proof that the donated
    ping-pong round does NOT double-buffer the resident state.
  * **Fusions** are atomic: internal temporaries are not modeled (XLA fuses
    exactly so they never materialize); only the fusion result allocates.
  * **Sub-computations** of ``while`` / ``conditional`` / ``call`` add their
    internal peak (minus their parameter bytes, which alias the caller's
    operands) as a transient at the call site.
"""
from __future__ import annotations

import re
from dataclasses import dataclass
from typing import Dict, FrozenSet, List, Optional, Tuple

from . import hlo

# ops whose result shares / forwards its operands' buffers
_VIEW_OPS = ("tuple", "get-tuple-element", "bitcast", "optimization-barrier",
             "while")

# ops whose sub-computations run with live caller state (transient peak);
# fusion's ``calls=`` and reduce/scatter/sort's scalar ``to_apply`` are
# deliberately NOT recursed
_TRANSIENT_ATTRS = {
    "while": ("body", "condition"),
    "conditional": ("true_computation", "false_computation",
                    "branch_computations"),
    "call": ("to_apply",),
}

_CALLED_RE = re.compile(
    r"(body|condition|true_computation|false_computation|to_apply|calls)"
    r"=%([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")

_ALIAS_ENTRY_RE = re.compile(r"\{([\d,\s]*)\}:\s*\(\s*(\d+)\s*,")


@dataclass(frozen=True)
class Instr:
    name: str
    op: str
    bytes: int
    operands: Tuple[str, ...]
    index: int
    is_root: bool
    called: Tuple[Tuple[str, str], ...]  # (attr, computation name)


@dataclass(frozen=True)
class MemoryEstimate:
    """Static memory profile of one compiled (per-device) program.

    peak_bytes        estimated peak live bytes at the worst schedule point
    peak_index        schedule position of that peak (ENTRY instruction idx)
    param_bytes       caller-supplied input bytes (live for the whole call)
    output_bytes      fresh output bytes (non-donated ROOT allocations)
    donated_collapsed bytes that donation aliasing removed from the peak
    top               largest live buffers at the peak: ((name, bytes), ...)
    """
    peak_bytes: int
    peak_index: int
    param_bytes: int
    output_bytes: int
    donated_collapsed: int
    top: Tuple[Tuple[str, int], ...]


def _shape_bytes(fragment: str) -> int:
    return sum(
        _elems(dims) * hlo._DTYPE_BYTES.get(dt, 0)
        for dt, dims in hlo.parse_shapes(fragment))


def _elems(dims: Tuple[int, ...]) -> int:
    e = 1
    for d in dims:
        e *= d
    return e


def _balanced(text: str, start: int) -> int:
    """Index one past the paren group opening at ``text[start] == '('``."""
    depth = 0
    for j in range(start, len(text)):
        if text[j] == "(":
            depth += 1
        elif text[j] == ")":
            depth -= 1
            if depth == 0:
                return j + 1
    return len(text)


_OPERAND_RE = re.compile(r"%([\w.\-]+)")


def _parse_instr(line: str, index: int) -> Optional[Instr]:
    s = line.strip()
    is_root = s.startswith("ROOT ")
    if is_root:
        s = s[len("ROOT "):].lstrip()
    if not s.startswith("%"):
        return None
    eq = s.find(" = ")
    if eq < 0:
        return None
    name = s[1:eq].strip()
    rest = s[eq + 3:]
    if rest.startswith("("):
        end = _balanced(rest, 0)
        result, tail = rest[:end], rest[end:]
    else:
        m = re.match(r"\S+", rest)
        if m is None:
            return None
        result, tail = m.group(0), rest[m.end():]
    tail = tail.lstrip()
    m = re.match(r"([a-zA-Z][\w\-]*)\(", tail)
    if m is None:
        return None
    op = m.group(1)
    span_end = _balanced(tail, m.end() - 1)
    operands = tuple(_OPERAND_RE.findall(tail[m.end():span_end - 1]))
    attrs = tail[span_end:]
    called: List[Tuple[str, str]] = list(_CALLED_RE.findall(attrs))
    bm = _BRANCHES_RE.search(attrs)
    if bm:
        called += [("branch_computations", c)
                   for c in _OPERAND_RE.findall(bm.group(1))]
    return Instr(name=name, op=op, bytes=_shape_bytes(result),
                 operands=operands, index=index, is_root=is_root,
                 called=tuple(called))


def split_computations(txt: str) -> Tuple[Dict[str, List[Instr]], Optional[str]]:
    """{computation name: scheduled instruction list} and the ENTRY name."""
    comps: Dict[str, List[Instr]] = {}
    cur: Optional[List[Instr]] = None
    entry: Optional[str] = None
    for line in txt.splitlines():
        st = line.strip()
        if cur is None:
            if (st.endswith("{") and "->" in st
                    and not st.startswith("HloModule")):
                m = re.match(r"(ENTRY\s+)?%?([\w.\-]+)", st)
                if m is None:
                    continue
                cur = comps.setdefault(m.group(2), [])
                if m.group(1):
                    entry = m.group(2)
            continue
        if st.startswith("}"):
            cur = None
            continue
        instr = _parse_instr(line, len(cur))
        if instr is not None:
            cur.append(instr)
    return comps, entry


def _output_aliases(txt: str) -> Dict[Optional[int], int]:
    """{ROOT tuple index (None = whole output): parameter number} from the
    module's ``input_output_alias`` header."""
    m = hlo._ALIAS_HDR_RE.search(txt)
    if m is None:
        return {}
    out: Dict[Optional[int], int] = {}
    for idx_str, param in _ALIAS_ENTRY_RE.findall(m.group(1)):
        parts = [p for p in idx_str.replace(",", " ").split() if p]
        out[int(parts[0]) if parts else None] = int(param)
    return out


class _Analyzer:
    def __init__(self, comps: Dict[str, List[Instr]]):
        self.comps = comps
        self._peak_memo: Dict[str, Tuple[int, int]] = {}

    def _resolve(self, comp: List[Instr], by_name: Dict[str, Instr],
                 name: str, memo: Dict[str, FrozenSet[str]]
                 ) -> FrozenSet[str]:
        """Underlying allocated values a (possibly view) value refers to."""
        if name in memo:
            return memo[name]
        memo[name] = frozenset()  # cycle guard (SSA: shouldn't trigger)
        instr = by_name.get(name)
        if instr is None:
            out: FrozenSet[str] = frozenset()
        elif instr.op in _VIEW_OPS or instr.op.endswith("-done"):
            out = frozenset().union(*(
                self._resolve(comp, by_name, o, memo)
                for o in instr.operands)) if instr.operands else frozenset()
        else:
            out = frozenset((name,))
        memo[name] = out
        return out

    def comp_profile(self, comp_name: str,
                     aliases: Optional[Dict[Optional[int], int]] = None
                     ) -> MemoryEstimate:
        comp = self.comps.get(comp_name, [])
        by_name = {i.name: i for i in comp}
        n = len(comp)
        memo: Dict[str, FrozenSet[str]] = {}
        param_bytes = sum(i.bytes for i in comp if i.op == "parameter")
        def_idx: Dict[str, int] = {}
        last: Dict[str, int] = {}
        bytes_of: Dict[str, int] = {}
        for i in comp:
            if i.op == "parameter":
                continue
            underlying = self._resolve(comp, by_name, i.name, memo)
            if i.name in underlying:  # a real allocation
                def_idx[i.name] = i.index
                last[i.name] = i.index
                bytes_of[i.name] = i.bytes
            for o in i.operands:
                for u in self._resolve(comp, by_name, o, memo):
                    if u in last:
                        last[u] = max(last[u], i.index)
        root = next((i for i in comp if i.is_root), comp[-1] if comp else None)
        donated = 0
        if root is not None:
            for u in self._resolve(comp, by_name, root.name, memo):
                if u in last:
                    last[u] = n
            if aliases:
                for out_idx, _param in aliases.items():
                    target: Optional[str] = None
                    if out_idx is None:
                        target = root.name
                    elif root.op == "tuple" and out_idx < len(root.operands):
                        target = root.operands[out_idx]
                    if target is None:
                        continue
                    for u in self._resolve(comp, by_name, target, memo):
                        if u in bytes_of and bytes_of[u] > 0:
                            donated += bytes_of[u]
                            bytes_of[u] = 0
                            break  # one buffer backs one output element
        # transient internal peaks of control-flow sub-computations
        transient = [0] * (n + 1)
        for i in comp:
            attrs = _TRANSIENT_ATTRS.get(i.op)
            if not attrs:
                continue
            t = 0
            for attr, callee in i.called:
                if attr not in attrs or callee not in self.comps:
                    continue
                sub_peak, sub_params = self._sub_peak(callee)
                t = max(t, max(0, sub_peak - sub_params))
            transient[min(i.index, n)] += t

        delta = [0] * (n + 2)
        for u, b in bytes_of.items():
            delta[def_idx[u]] += b
            delta[last[u] + 1] -= b
        delta[0] += param_bytes
        peak, peak_idx, run = 0, 0, 0
        for idx in range(n + 1):
            run += delta[idx]
            here = run + (transient[idx] if idx < len(transient) else 0)
            if here > peak:
                peak, peak_idx = here, idx
        output_bytes = 0
        if root is not None:
            out_underlying = self._resolve(comp, by_name, root.name, memo)
            output_bytes = sum(bytes_of.get(u, 0) for u in out_underlying)
        top = sorted(
            ((u, b) for u, b in bytes_of.items()
             if b > 0 and def_idx[u] <= peak_idx <= last[u]),
            key=lambda kv: -kv[1])[:5]
        if peak_idx == 0 or param_bytes >= peak:
            top = [("(parameters)", param_bytes)] + top
        return MemoryEstimate(peak_bytes=peak, peak_index=peak_idx,
                              param_bytes=param_bytes,
                              output_bytes=output_bytes,
                              donated_collapsed=donated,
                              top=tuple(top[:5]))

    def _sub_peak(self, comp_name: str) -> Tuple[int, int]:
        if comp_name not in self._peak_memo:
            self._peak_memo[comp_name] = (0, 0)  # cycle guard
            est = self.comp_profile(comp_name)
            self._peak_memo[comp_name] = (est.peak_bytes, est.param_bytes)
        return self._peak_memo[comp_name]


def analyze(txt: str) -> MemoryEstimate:
    """Static per-device memory profile of a compiled module's ENTRY."""
    comps, entry = split_computations(txt)
    if entry is None:
        raise ValueError("no ENTRY computation found in HLO text")
    return _Analyzer(comps).comp_profile(entry, _output_aliases(txt))


def peak_live_bytes(txt: str) -> int:
    """Estimated per-device peak live bytes of a compiled module."""
    return analyze(txt).peak_bytes
