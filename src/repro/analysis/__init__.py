"""repro.analysis — static analysis for compiled FL round programs.

Three layers (see ``README.md`` in this directory):

  * ``hlo`` / ``jaxpr`` — the ONE copy of the HLO-text and jaxpr parsing
    rules (typed ``CollectiveOp`` records with source provenance,
    donation-alias parsing, the read/sort jaxpr visitor);
  * ``memory`` / ``blame`` — live-interval analysis over the scheduled
    instruction sequence (statically estimated per-device peak bytes,
    donation collapsing) and collective-to-source attribution via HLO
    ``metadata`` (which Python line introduced each collective);
  * ``contracts`` — declarative ``Contract`` objects that programs
    declare next to their builders and every gate site evaluates;
  * ``passes`` / ``lint`` — runtime-adjacent checks (donation, recompile
    auditing, cache hygiene) and FL-specific AST source lints.

CLI: ``python -m repro.analysis check`` (lower the canonical program set
under forced multi-device meshes and print the full contract table) and
``python -m repro.analysis lint src/``.
"""
from repro.analysis import (blame, hlo, jaxpr, lint,  # noqa: F401
                            memory, passes)
from repro.analysis.contracts import (Bound, Contract, Report,  # noqa: F401
                                      format_table)
