"""Runtime-adjacent analysis passes: donation verification, recompile
auditing, and cache hygiene.

These passes check the properties that only exist at run time — whether
XLA actually materialized the requested ``input_output_aliases`` for the
resident ping-pong buffers, and whether the compiled-program caches
(``round._ROUND_CACHE``, ``ResidentDriver._cbufs``) behave: a cache key
that under-discriminates (the PR 5/6 bug class: keys missing the mesh or
the padded row count) shows up here as a key collision or a silent wrong-
program hit; a key that over-discriminates shows up as an unexpected
retrace.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Dict, Iterable, List, Tuple

from repro.analysis import hlo as hlo_mod


def check_donation(txt: str, expected: Iterable[int]) -> List[str]:
    """Violation messages for donations that did NOT materialize in a
    compiled program's ``input_output_alias`` header.

    ``expected`` are flattened parameter indices (the resident round
    donates params 0 and 1: g_buf and the cohort scratch).  A donation
    XLA drops (shape/sharding mismatch between the donated input and
    every output) is silent — the program still runs, resident memory
    just doubles — so no numeric test catches it; this pass does.
    """
    donated = hlo_mod.donated_params(txt)
    return [f"donation of parameter {p} not materialized "
            f"(aliased params: {sorted(donated) or 'none'})"
            for p in sorted(set(expected)) if p not in donated]


class _InstrumentedCache(OrderedDict):
    """OrderedDict recording (event, key) for every hit / insert / evict."""

    def __init__(self, src, events: List[Tuple[str, Tuple]]):
        self._events = []       # swallow the pre-existing entries' inserts
        super().__init__(src)
        self._events = events

    def get(self, key, default=None):
        val = super().get(key, default)
        if val is not default:
            self._events.append(("hit", key))
        return val

    def __setitem__(self, key, value):
        if key not in self:
            self._events.append(("insert", key))
        super().__setitem__(key, value)

    def popitem(self, last=True):
        key, value = super().popitem(last)
        self._events.append(("evict", key))
        return key, value


class RecompileAuditor:
    """Context manager instrumenting ``round._ROUND_CACHE``.

    Records every program-cache hit, insert (a retrace + compile) and LRU
    evict while active — the async admit/merge programs share the same
    cache, so one auditor sees all round-program builds.  Use it to pin
    cache behavior across mesh/pad/row-count variations::

        with RecompileAuditor() as aud:
            make_flat_round(cfg, fl, index, any_malicious=False, mesh=m1)
            make_flat_round(cfg, fl, index, any_malicious=False, mesh=m1b)
        assert aud.inserts == 1 and aud.hits == 1   # rebuilt-equal mesh hits

    An insert where a hit was expected is an *unexpected retrace* (key
    over-discriminates, e.g. keying a mesh by object identity); a hit
    where an insert was expected means the key under-discriminates (the
    PR 6 ``_cbufs`` bug class) — ``report()`` gives the counts, ``events``
    the full (event, key) sequence for forensics.
    """

    def __init__(self):
        self.events: List[Tuple[str, Tuple]] = []

    def __enter__(self) -> "RecompileAuditor":
        from repro.core import round as round_mod
        self._round_mod = round_mod
        self._orig = round_mod._ROUND_CACHE
        round_mod._ROUND_CACHE = _InstrumentedCache(self._orig, self.events)
        return self

    def __exit__(self, *exc) -> None:
        # fold mutations back into a plain OrderedDict so nothing keeps
        # recording after the audit window
        self._round_mod._ROUND_CACHE = OrderedDict(
            self._round_mod._ROUND_CACHE)
        return None

    def _count(self, kind: str) -> int:
        return sum(1 for e, _ in self.events if e == kind)

    @property
    def hits(self) -> int:
        return self._count("hit")

    @property
    def inserts(self) -> int:
        return self._count("insert")

    @property
    def evictions(self) -> int:
        return self._count("evict")

    def report(self) -> Dict[str, int]:
        return {"hits": self.hits, "inserts": self.inserts,
                "evictions": self.evictions}


def check_cache_keys(keyed: Iterable[Tuple[str, Tuple]]) -> List[str]:
    """Collision messages over (label, cache key) pairs: two DIFFERENT
    labels mapping to the same key means the key under-discriminates —
    those variants would silently share one compiled program (the PR 5/6
    bug class: a key missing the mesh, the pad width, or the row count).
    Pass keys built with ``round._round_key`` / the async program keys.
    """
    seen: Dict[Tuple, str] = {}
    out: List[str] = []
    for label, key in keyed:
        prev = seen.get(key)
        if prev is not None and prev != label:
            out.append(f"cache-key collision: {prev!r} and {label!r} "
                       f"share one compiled-program cache entry")
        seen.setdefault(key, label)
    return out


def audit_cbufs(driver) -> List[str]:
    """Hygiene check over a ``ResidentDriver``-style scratch pool
    (``._cbufs``: padded row count -> (rows, N) buffer): every key must
    equal its buffer's actual row count, and no deleted (donated-away)
    buffer may stay referenced.  Both were real bugs (PR 6): keying on the
    raw cohort size held one never-donated buffer per real size and
    retained dead donated buffers forever.
    """
    out: List[str] = []
    for rows, buf in getattr(driver, "_cbufs", {}).items():
        if buf.is_deleted():
            out.append(f"_cbufs[{rows}] holds a deleted buffer "
                       f"(donated elsewhere but never evicted)")
            continue
        if buf.shape[0] != rows:
            out.append(f"_cbufs[{rows}] buffer has {buf.shape[0]} rows — "
                       f"key does not match the padded shape")
    return out
