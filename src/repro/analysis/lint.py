"""FL-specific source lints (AST pass) for bug classes this repo has paid
for.  Run as ``python -m repro.analysis lint src/`` (also in tier-1 via
``tests/test_analysis.py``).

Each rule carries the PR/bug that motivated it in its docstring.
Suppress a finding with ``# noqa: <rule-id>`` (or a bare ``# noqa``) on
the offending line.
"""
from __future__ import annotations

import ast
import re
from dataclasses import dataclass
from pathlib import Path
from typing import Iterable, List, Optional, Sequence

_NOQA_RE = re.compile(r"#\s*noqa(?::\s*(?P<codes>[\w\-, ]+))?", re.IGNORECASE)


@dataclass(frozen=True)
class Finding:
    """One lint violation."""
    path: str
    line: int
    col: int
    rule: str
    message: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: [{self.rule}] " \
               f"{self.message}"


def _dotted(node: ast.AST) -> Optional[str]:
    """``a.b.c`` for an Attribute/Name chain, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _suppressed(src_lines: Sequence[str], line: int, rule: str) -> bool:
    if not 1 <= line <= len(src_lines):
        return False
    m = _NOQA_RE.search(src_lines[line - 1])
    if m is None:
        return False
    codes = m.group("codes")
    if codes is None:
        return True
    return rule in {c.strip() for c in codes.split(",")}


# --------------------------------------------------------------------------
# rule: traced-random-split
# --------------------------------------------------------------------------

def _jitted_nodes(tree: ast.Module) -> set:
    """The FunctionDef NODES the module jits: ``@jax.jit``-decorated,
    ``@partial(jax.jit, ...)``-decorated, or passed to a ``jax.jit(...)``
    call.  Call-form references resolve lexically (a ``jax.jit(_fn)``
    inside a builder marks the sibling ``_fn`` closure, NOT an unrelated
    method that happens to share the name — e.g. ``AsyncEngine._merge``
    vs the jitted ``_merge`` closure in ``make_merge_program``)."""
    jitted = set()

    def is_jit(node: ast.AST) -> bool:
        return _dotted(node) in ("jax.jit", "jit")

    def handle_decorators(fn) -> None:
        for dec in fn.decorator_list:
            if is_jit(dec):
                jitted.add(fn)
            elif isinstance(dec, ast.Call):
                if is_jit(dec.func):
                    jitted.add(fn)
                elif _dotted(dec.func) in ("functools.partial", "partial") \
                        and dec.args and is_jit(dec.args[0]):
                    jitted.add(fn)

    def visit(body: Iterable[ast.stmt], env: dict) -> None:
        local = dict(env)
        for stmt in body:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                local[stmt.name] = stmt
        for stmt in body:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                handle_decorators(stmt)
                visit(stmt.body, local)
            elif isinstance(stmt, ast.ClassDef):
                visit(stmt.body, env)  # methods aren't bare names in scope
            else:
                for node in ast.walk(stmt):
                    if isinstance(node, ast.Call) and is_jit(node.func):
                        for arg in node.args[:1]:
                            name = _dotted(arg)
                            tail = name.split(".")[-1] if name else None
                            if tail in local:
                                jitted.add(local[tail])

    visit(tree.body, {})
    return jitted


def _jitted_names(tree: ast.Module) -> set:
    """Names of the jitted functions (see ``_jitted_nodes``)."""
    return {n.name for n in _jitted_nodes(tree)}


def check_traced_random_split(tree: ast.Module, path: str,
                              src_lines: Sequence[str]) -> List[Finding]:
    """No traced ``jax.random.split`` inside jitted round-program code.

    Motivated by PR 5: per-client PRNG keys MUST be split host-side —
    ``jax.random.split`` traced under a 2-D (data, model) mesh produces
    different threefry bits than the same split on one device, silently
    breaking cross-mesh parity.  ``flat_round``/``fl_round`` split on host
    and pass the key batch in as data; a split that sneaks back inside a
    jitted program reintroduces the divergence with no test failing until
    the mesh shape changes.
    """
    rule = "traced-random-split"
    jitted = _jitted_nodes(tree)
    out: List[Finding] = []

    def scan(fn: ast.AST, owner: str) -> None:
        for node in ast.walk(fn):
            if isinstance(node, ast.Call) and _dotted(node.func) in (
                    "jax.random.split", "random.split", "jrandom.split"):
                if not _suppressed(src_lines, node.lineno, rule):
                    out.append(Finding(
                        path, node.lineno, node.col_offset, rule,
                        f"jax.random.split traced inside jitted "
                        f"function {owner!r}; split keys host-side and "
                        f"pass the batch in (PR 5 threefry-parity bug)"))

    for node in jitted:
        scan(node, node.name)
    return out


# --------------------------------------------------------------------------
# rule: bare-assert
# --------------------------------------------------------------------------

def check_bare_assert(tree: ast.Module, path: str,
                      src_lines: Sequence[str]) -> List[Finding]:
    """No bare ``assert`` for input validation outside kernels.

    Motivated by PR 3: ``checkpoint.restore`` validated restored
    structures with ``assert``, which vanishes under ``python -O`` —
    corrupt checkpoints loaded silently.  Validation must raise
    ``ValueError``/``TypeError`` with the offending value in the message.
    Kernel-internal shape asserts (``src/repro/kernels/``) are exempt:
    they are developer invariants on traced shapes, not input validation.
    """
    rule = "bare-assert"
    norm = path.replace("\\", "/")
    if "/kernels/" in norm or norm.startswith("kernels/"):
        return []
    out: List[Finding] = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Assert) \
                and not _suppressed(src_lines, node.lineno, rule):
            out.append(Finding(
                path, node.lineno, node.col_offset, rule,
                "bare assert is stripped under python -O; raise "
                "ValueError with the offending value instead "
                "(PR 3 checkpoint.restore bug)"))
    return out


# --------------------------------------------------------------------------
# rule: import-time-jnp
# --------------------------------------------------------------------------

def check_import_time_jnp(tree: ast.Module, path: str,
                          src_lines: Sequence[str]) -> List[Finding]:
    """No ``jnp`` / jax-array calls at module import time.

    Motivated by the mesh/launch design (PR 3/5): the first jax array op
    initializes the backend and FREEZES the device topology, so a
    module-level ``jnp.(...)`` call makes ``XLA_FLAGS=
    --xla_force_host_platform_device_count=N`` (and any future
    ``jax.distributed.initialize``) silently ineffective for every later
    import.  ``launch/mesh.py`` keeps meshes behind functions for exactly
    this reason; constants belong inside functions or plain Python.
    """
    rule = "import-time-jnp"
    out: List[Finding] = []

    def scan(body: Iterable[ast.stmt]) -> None:
        for stmt in body:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            if isinstance(stmt, ast.ClassDef):
                scan(stmt.body)
                continue
            for node in ast.walk(stmt):
                if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                     ast.Lambda)):
                    # deferred bodies don't run at import
                    for inner in ast.walk(node):
                        inner._repro_deferred = True  # type: ignore
                    continue
                if getattr(node, "_repro_deferred", False):
                    continue
                if isinstance(node, ast.Call):
                    name = _dotted(node.func) or ""
                    if name.startswith(("jnp.", "jax.numpy.")) \
                            and not _suppressed(src_lines, node.lineno,
                                                rule):
                        out.append(Finding(
                            path, node.lineno, node.col_offset, rule,
                            f"{name} called at module import time; this "
                            f"initializes the jax backend and freezes "
                            f"the device topology before XLA_FLAGS / "
                            f"distributed init can take effect"))

    scan(tree.body)
    return out


# --------------------------------------------------------------------------
# rule: host-sync-in-program
# --------------------------------------------------------------------------

_HOST_SYNC_NP = ("np.asarray", "numpy.asarray", "np.array", "numpy.array")


def check_host_sync_in_program(tree: ast.Module, path: str,
                               src_lines: Sequence[str]) -> List[Finding]:
    """No host synchronization on traced values inside jitted programs.

    Motivated by the PR 6 incremental-loss-conversion bug class:
    ``float(...)``, ``.item()`` and ``np.asarray(...)`` applied to a
    traced value inside a jitted round/aggregation function either raise a
    ``ConcretizationTypeError`` at trace time or — worse, when the value
    is a closed-over constant — silently bake a stale host value into the
    compiled program.  Host conversion belongs OUTSIDE the program, on its
    returned arrays (as ``run_rounds``/``run_async`` do per merge).
    """
    rule = "host-sync-in-program"
    jitted = _jitted_nodes(tree)
    out: List[Finding] = []

    def offending(node: ast.AST) -> Optional[str]:
        if not isinstance(node, ast.Call):
            return None
        name = _dotted(node.func)
        if name == "float" or name in _HOST_SYNC_NP:
            return f"{name}(...)"
        if isinstance(node.func, ast.Attribute) and node.func.attr == "item" \
                and not node.args:
            return ".item()"
        return None

    def scan(fn: ast.AST, owner: str) -> None:
        for node in ast.walk(fn):
            what = offending(node)
            if what and not _suppressed(src_lines, node.lineno, rule):
                out.append(Finding(
                    path, node.lineno, node.col_offset, rule,
                    f"{what} on a traced value inside jitted function "
                    f"{owner!r} forces a host sync (or bakes in a stale "
                    f"constant); convert on the program's OUTPUTS instead "
                    f"(PR 6 incremental-loss-conversion bug)"))

    for node in jitted:
        scan(node, node.name)
    return out


RULES = (check_traced_random_split, check_bare_assert,
         check_import_time_jnp, check_host_sync_in_program)


def lint_source(src: str, path: str = "<string>") -> List[Finding]:
    """Run every rule over one source string."""
    try:
        tree = ast.parse(src, filename=path)
    except SyntaxError as e:
        return [Finding(path, e.lineno or 0, e.offset or 0, "syntax-error",
                        str(e.msg))]
    lines = src.splitlines()
    out: List[Finding] = []
    for rule in RULES:
        out.extend(rule(tree, path, lines))
    return sorted(out, key=lambda f: (f.path, f.line, f.col))


def lint_paths(paths: Sequence[str]) -> List[Finding]:
    """Lint every ``*.py`` under the given files/directories."""
    files: List[Path] = []
    for p in paths:
        pp = Path(p)
        if pp.is_dir():
            files.extend(sorted(pp.rglob("*.py")))
        else:
            files.append(pp)
    out: List[Finding] = []
    for f in files:
        out.extend(lint_source(f.read_text(), str(f)))
    return out
