"""Reusable jaxpr visitor: per-operand reads, sorts, gathers and scatters.

Generalizes the read/sort walk that used to live inline in
``benchmarks/bench_quantile.py``: the fused trimmed-quantile invariant
(each cohort row read exactly ONCE, zero sorts — vs the top_k tail path's
7 reads and 1 sort) is a *traced-program* property, so it is measured on
the jaxpr, not on timing.  ``repro.analysis.contracts.Contract`` consumes
these counts via its ``row_reads``/``sorts`` fields.

Counting rules:

  * a **read** is a compute eqn with at least one operand of exactly
    ``row_elems`` elements (the row block being measured); pure
    layout/dtype plumbing (``LAYOUT_PRIMS``) is excluded — XLA fuses it
    away, it is not a memory pass;
  * a ``pallas_call`` counts as ONE read (when row-block-sized) and is NOT
    recursed into: its inner jaxpr is VMEM-resident work, which is exactly
    the fusion being measured;
  * other call-like eqns (jit, custom_jvp, scan, shard_map, ...) are
    recursed through transparently;
  * **sorts** (``SORT_PRIMS``), **gathers** and **scatters** are counted
    wherever they appear (except inside pallas_call, per the rule above),
    regardless of operand size.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, List, Optional

# layout/dtype plumbing, not memory passes in a fused XLA program
LAYOUT_PRIMS = frozenset({
    "reshape", "broadcast_in_dim", "squeeze", "transpose",
    "convert_element_type", "copy", "slice"})
SORT_PRIMS = frozenset({"sort", "top_k", "approx_top_k"})
GATHER_PRIMS = frozenset({"gather", "dynamic_slice", "take"})
SCATTER_PRIMS = frozenset({
    "scatter", "scatter-add", "scatter-mul", "scatter-min", "scatter-max",
    "dynamic_update_slice"})


@dataclass
class Counts:
    """Aggregated op counts of one jaxpr walk."""
    reads: int = 0
    sorts: int = 0
    gathers: int = 0
    scatters: int = 0

    def __iadd__(self, other: "Counts") -> "Counts":
        self.reads += other.reads
        self.sorts += other.sorts
        self.gathers += other.gathers
        self.scatters += other.scatters
        return self


def sub_jaxprs(eqn) -> List[Any]:
    """Every sub-jaxpr held in an eqn's params (call-like eqns: jit, scan,
    cond, custom_*, shard_map, pallas_call...)."""
    import jax
    out = []
    for v in eqn.params.values():
        for u in (v if isinstance(v, (list, tuple)) else [v]):
            if isinstance(u, jax.extend.core.ClosedJaxpr):
                out.append(u.jaxpr)
            elif isinstance(u, jax.extend.core.Jaxpr):
                out.append(u)
    return out


def walk(jaxpr, row_elems: Optional[int] = None) -> Counts:
    """Count reads/sorts/gathers/scatters over a jaxpr (recursive).

    ``jaxpr`` may be a ``Jaxpr`` or ``ClosedJaxpr``.  ``row_elems`` selects
    the operand size whose reads are counted; with None, ``reads`` stays 0
    and only the op-class counters are filled.
    """
    if hasattr(jaxpr, "jaxpr"):             # ClosedJaxpr
        jaxpr = jaxpr.jaxpr
    c = Counts()
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        rowsized = row_elems is not None and any(
            getattr(v, "aval", None) is not None
            and v.aval.size == row_elems for v in eqn.invars)
        if name == "pallas_call":
            c.reads += bool(rowsized)
            continue
        subs = sub_jaxprs(eqn)
        if subs:
            for s in subs:
                c += walk(s, row_elems)
            continue
        if name in SORT_PRIMS:
            c.sorts += 1
        if name in GATHER_PRIMS:
            c.gathers += 1
        if name in SCATTER_PRIMS:
            c.scatters += 1
        if rowsized and name not in LAYOUT_PRIMS:
            c.reads += 1
    return c


def trace_counts(fn, *args, row_elems: Optional[int] = None, **kwargs
                 ) -> Counts:
    """Trace ``fn(*args, **kwargs)`` and walk the resulting jaxpr."""
    import jax
    return walk(jax.make_jaxpr(fn)(*args, **kwargs), row_elems=row_elems)
