"""The canonical program set for ``python -m repro.analysis check``.

Lowers + compiles the programs whose structure the repo's invariants live
on — the sync resident round (data-only and 2x2 (data, model) mesh), the
standalone aggregation path, the async admit + merge programs, and the
fused trimmed-quantile pass — and evaluates each against the contract its
OWN module declares (``core.round.round_contract``,
``core.async_round.admit_contract``/``merge_contract``,
``kernels.fedfa_agg.ops.accumulate_contract``,
``kernels.fedfa_quantile.ops.fused_quantile_contract``).

Needs a multi-device backend for the collectives to exist; the CLI
re-execs itself under ``--xla_force_host_platform_device_count=4`` when
the host has fewer (the flag is read at jax init, so it cannot be set in
an already-initialized process).

The fixture is deliberately tiny (the reduced smollm-135m the test suite
and benchmarks also use) — contracts are about program STRUCTURE, which
is shape-independent beyond the mesh divisibility constraints.
"""
from __future__ import annotations

from typing import Callable, List, Tuple

from repro.analysis.contracts import Report


def _fixture(m: int, local_steps: int = 1, batch: int = 2,
             seq_len: int = 8, seed: int = 0):
    import jax
    import jax.numpy as jnp
    from repro.configs import get_arch
    from repro.core.server import FLConfig, make_client_specs
    from repro.data import partition as part_mod
    from repro.data import pipeline, synthetic
    from repro.launch.train import client_arch_pool
    from repro.models import model as model_mod

    n_classes = 10
    cfg = get_arch("smollm-135m").reduced().replace(
        n_layers=4, n_sections=2, vocab_size=64, tie_embeddings=False)
    params = model_mod.init_params(cfg, jax.random.PRNGKey(seed))
    specs = make_client_specs(cfg, m, archs=client_arch_pool(cfg, "width"),
                              seed=seed)
    parts = part_mod.iid_partition(m, n_classes, seed=seed)
    profiles = synthetic.make_class_profiles(n_classes, cfg.vocab_size,
                                             seed=seed)
    b = pipeline.round_batches_cls(
        parts, list(range(m)), n_classes, cfg.vocab_size,
        local_steps=local_steps, batch=batch, seq_len=seq_len,
        profiles=profiles, seed=100)
    batches = {k: jnp.asarray(v) for k, v in b.items()}
    # the kernelized configuration (interpret mode off-TPU) — the
    # structural contracts describe the kernel path
    fl = FLConfig(local_steps=local_steps, lr=0.05, strategy="fedfa",
                  task="cls", agg_engine="flat", use_kernel=True,
                  interpret=True)
    return cfg, fl, params, specs, batches


def _padded_inputs(cfg, fl, params, specs, batches, mesh, rows=None):
    """(index, m_real, rows, padded runtime tuple, padded batches)."""
    from repro.core import flat
    from repro.core.server import default_class_masks, stack_runtimes
    from repro.sharding import cohort as csh

    index = flat.get_index(params, pad_to=csh.pad_unit(mesh))
    runtimes = stack_runtimes(cfg, specs)
    m = len(specs)
    pad = (rows - m) if rows is not None else csh.pad_rows(m, mesh)
    m_real = m if pad else None
    (masks, gates, gmaps, nd, cms, mal), bpad = csh.pad_cohort(
        runtimes, batches, pad)
    mp = m + pad
    cms_in = default_class_masks(cms, cfg, fl, mp)
    return index, m_real, mp, (masks, gates, gmaps, nd, cms_in, mal), bpad


def round_report(mesh, m: int = 3) -> Report:
    """Lower + compile the resident round under ``mesh``; check its
    declared contract (donated ping-pong, no full-cohort gather, data-only
    mesh: zero all-gathers + >= 1 N-sized psum)."""
    import jax
    import jax.numpy as jnp
    from repro.core import flat
    from repro.core import round as round_mod
    from repro.sharding import cohort as csh

    cfg, fl, params, specs, batches = _fixture(m)
    index, m_real, mp, (masks, gates, gmaps, nd, cms_in, mal), bpad = \
        _padded_inputs(cfg, fl, params, specs, batches, mesh)
    g = jax.device_put(flat.flatten(index, params),
                       csh.global_sharding(mesh))
    c = jax.device_put(jnp.zeros((mp, index.n_padded), jnp.float32),
                       csh.cohort_buffer_sharding(mesh))
    fn = round_mod.make_flat_round(cfg, fl, index, any_malicious=False,
                                   mesh=mesh, m_real=m_real)
    keys = jax.random.split(jax.random.PRNGKey(0), mp)
    txt = fn.lower(g, c, masks, gates, gmaps, nd, cms_in, mal, bpad,
                   keys).compile().as_text()
    return round_mod.round_contract(index, mesh, rows=mp).check(hlo=txt)


def quant_round_report(mesh, m: int = 3) -> Report:
    """Lower + compile the QUANTIZED resident round (int8 admission with
    per-segment scales + server-side error feedback) on the data mesh and
    check ``quantized_round_contract``: all five resident pools donated,
    zero all-gathers, the sub-f32 peak budget — plus the read-once /
    sort-free structure of the fused dequantize-accumulate, measured on a
    standalone ``accumulate_quant`` trace over the int8 rows (the full
    round's jaxpr touches row-sized f32 transients during training, so
    the kernel invariant is pinned where it lives)."""
    import dataclasses

    import jax
    import jax.numpy as jnp
    from repro.core import flat
    from repro.core import round as round_mod
    from repro.kernels.fedfa_agg import ops as agg_ops
    from repro.sharding import cohort as csh

    cfg, fl, params, specs, batches = _fixture(m)
    fl = dataclasses.replace(fl, update_dtype="int8")
    index, m_real, mp, (masks, gates, gmaps, nd, cms_in, mal), bpad = \
        _padded_inputs(cfg, fl, params, specs, batches, mesh)
    g = jax.device_put(flat.flatten(index, params),
                       csh.global_sharding(mesh))
    S = index.n_segments
    cb, co = csh.cohort_buffer_sharding(mesh), csh.cohort_sharding(mesh)
    state = round_mod.fresh_quant_state(index, mp, fl.update_dtype)
    xq, sc, eq, es = (jax.device_put(b, s)
                      for b, s in zip(state, (cb, co, cb, co)))
    fn = round_mod.make_flat_round(cfg, fl, index, any_malicious=False,
                                   mesh=mesh, m_real=m_real)
    keys = jax.random.split(jax.random.PRNGKey(0), mp)
    txt = fn.lower(g, xq, sc, eq, es, masks, gates, gmaps, nd, cms_in, mal,
                   bpad, keys).compile().as_text()

    seg_id, _, _ = flat._segment_maps(index)
    ones_n = jnp.ones((index.n_padded,), jnp.float32)

    def acc(x_q, w, wtab):
        return agg_ops.accumulate_quant(x_q, w, wtab, jnp.asarray(seg_id),
                                        ones_n, use_kernel=True,
                                        interpret=True)

    jaxpr = jax.make_jaxpr(acc)(
        jnp.zeros((mp, index.n_padded), jnp.int8),
        jnp.ones((mp,), jnp.float32), jnp.ones((mp, S), jnp.float32))
    return round_mod.quantized_round_contract(index, mesh, rows=mp).check(
        hlo=txt, jaxpr=jaxpr, row_elems=mp * index.n_padded)


def agg_report(mesh, m: int = 3) -> Report:
    """Lower the aggregation path standalone on the round's own shardings
    (g over ``model``, cohort rows over ``data`` pre-split) and check the
    ``accumulate`` contract: zero all-gathers, reduce-scattered (M', γ)
    sums capped at N/n_model per all-reduce with model shards."""
    import jax
    import jax.numpy as jnp
    from repro.core import flat
    from repro.kernels.fedfa_agg import ops as agg_ops
    from repro.sharding import cohort as csh

    cfg, fl, params, specs, batches = _fixture(m)
    index, _, mp, (masks, gates, gmaps, nd, _, _), _ = _padded_inputs(
        cfg, fl, params, specs, batches, mesh)
    g = jax.device_put(flat.flatten(index, params),
                       csh.global_sharding(mesh))
    x = jax.device_put(
        jax.random.normal(jax.random.PRNGKey(1), (mp, index.n_padded),
                          jnp.float32), csh.cohort_sharding(mesh))
    fn = jax.jit(lambda g, x, nd: flat.aggregate_buffers(
        index, g, x, cfg, masks, gates, gmaps, nd, graft=True, scale=True,
        use_kernel=True, interpret=True, mesh=mesh),
        out_shardings=csh.global_sharding(mesh))
    txt = fn.lower(g, x, nd).compile().as_text()
    return agg_ops.accumulate_contract(index.n_padded, mesh, rows=mp,
                                       segs=index.n_segments).check(hlo=txt)


def admit_report(mesh, capacity: int = 3) -> Report:
    """Lower the async admit program for one pool shape and check its
    contract (pool never gathered, pool buffer donation materialized)."""
    import jax
    import jax.numpy as jnp
    from repro.core import async_round
    from repro.core import flat
    from repro.sharding import cohort as csh

    cfg, fl, params, specs, batches = _fixture(capacity)
    rows = capacity + csh.pad_rows(capacity, mesh)
    index, _, _, (masks, gates, gmaps, _, cms_in, mal), bpad = _padded_inputs(
        cfg, fl, params, specs, batches, mesh, rows=rows)
    g = jax.device_put(flat.flatten(index, params),
                       csh.global_sharding(mesh))
    c = jax.device_put(jnp.zeros((rows, index.n_padded), jnp.float32),
                       csh.cohort_buffer_sharding(mesh))
    keys = jax.random.split(jax.random.PRNGKey(0), rows)
    written = jnp.ones((rows,), dtype=jnp.int32)
    fn = async_round.make_admit_program(cfg, fl, index,
                                        any_malicious=False, mesh=mesh,
                                        rows=rows)
    txt = fn.lower(g, c, masks, gates, gmaps, cms_in, mal, bpad, keys,
                   written).compile().as_text()
    return async_round.admit_contract(index, mesh, rows=rows).check(hlo=txt)


def quant_admit_report(mesh, capacity: int = 3) -> Report:
    """Lower the QUANTIZED async admit program (train + error feedback +
    quantize + slot select over the split pool) and check
    ``quantized_admit_contract``: all four pool pieces donated, zero
    all-gathers, no sort anywhere in the traced program (the per-segment
    scale max is a scatter-max, not a partition)."""
    import dataclasses

    import jax
    import jax.numpy as jnp
    from repro.core import async_round
    from repro.core import flat
    from repro.core import round as round_mod
    from repro.sharding import cohort as csh

    cfg, fl, params, specs, batches = _fixture(capacity)
    fl = dataclasses.replace(fl, update_dtype="int8")
    rows = capacity + csh.pad_rows(capacity, mesh)
    index, _, _, (masks, gates, gmaps, _, cms_in, mal), bpad = _padded_inputs(
        cfg, fl, params, specs, batches, mesh, rows=rows)
    g = jax.device_put(flat.flatten(index, params),
                       csh.global_sharding(mesh))
    cb, co = csh.cohort_buffer_sharding(mesh), csh.cohort_sharding(mesh)
    state = round_mod.fresh_quant_state(index, rows, fl.update_dtype)
    xq, sc, eq, es = (jax.device_put(b, s)
                      for b, s in zip(state, (cb, co, cb, co)))
    keys = jax.random.split(jax.random.PRNGKey(0), rows)
    written = jnp.ones((rows,), dtype=jnp.int32)
    fn = async_round.make_admit_program(cfg, fl, index,
                                        any_malicious=False, mesh=mesh,
                                        rows=rows)
    args = (g, xq, sc, eq, es, masks, gates, gmaps, cms_in, mal, bpad,
            keys, written)
    txt = fn.lower(*args).compile().as_text()
    jaxpr = jax.make_jaxpr(fn)(*args)
    return async_round.quantized_admit_contract(index, mesh,
                                                rows=rows).check(
        hlo=txt, jaxpr=jaxpr)


def merge_report(mesh, capacity: int = 3) -> Report:
    """Lower the async bounded-staleness merge and check its contract
    (zero all-gathers over the whole-row pool, g_buf donation)."""
    import jax
    import jax.numpy as jnp
    from repro.core import async_round
    from repro.core import flat
    from repro.sharding import cohort as csh

    cfg, fl, params, specs, batches = _fixture(capacity)
    rows = capacity + csh.pad_rows(capacity, mesh)
    index, _, _, (masks, gates, gmaps, _, _, _), _ = _padded_inputs(
        cfg, fl, params, specs, batches, mesh, rows=rows)
    g = jax.device_put(flat.flatten(index, params),
                       csh.global_sharding(mesh))
    c = jax.device_put(jnp.zeros((rows, index.n_padded), jnp.float32),
                       csh.cohort_buffer_sharding(mesh))
    w = jnp.arange(rows, dtype=jnp.float32)
    fn = async_round.make_merge_program(cfg, fl, index, mesh=mesh,
                                        rows=rows)
    txt = fn.lower(g, c, masks, gates, gmaps, w).compile().as_text()
    return async_round.merge_contract(index, mesh, rows=rows).check(hlo=txt)


def quantile_reports(m: int = 4, r: int = 8, length: int = 512,
                     trim: float = 0.95) -> List[Report]:
    """Trace the trimmed-norm paths and check the jaxpr contracts.
    Three fixtures: the dividing (m, r, length) row block (fused = 1 row
    read / 0 sorts, top_k tail = the pinned 7 reads / 1 sort reference),
    a NON-dividing block whose (Rp, Lp) staging pad re-anchors the padded
    peak budgets (``quantile/fused-pad`` / ``quantile/topk-pad``), and a
    single-pass-budget-exceeding long row that must dispatch to the
    two-stage multilevel kernel (``quantile/multilevel`` — still 1 read /
    0 sorts, NOT the jnp oracle).  All are also compiled so the
    peak-live-bytes budget (a multiple of the row-block size) is checked
    on the scheduled module."""
    import jax
    import jax.numpy as jnp
    from repro.core import flat
    from repro.kernels.fedfa_quantile import multilevel as q_ml
    from repro.kernels.fedfa_quantile import ops as q_ops

    def topk(rows, q):
        ra = jnp.abs(rows)
        t = flat._row_quantile(ra, q, trim)
        return jnp.sqrt(flat._rows_trimmed_sq(ra, t))

    def fused(rows, q):
        _, sq = flat._rows_trimmed_stats(rows, q, trim, True, True)
        return jnp.sqrt(sq)

    out = []
    # (shape, padded): length = 500 leaves Lp = 512 != L and Rp = 24 != 21,
    # exercising the staged zero-padded dispatch of ops.row_trimmed_stats
    for shape, padded in (((m, r, length), False), ((3, 7, 500), True)):
        rows = jax.random.normal(jax.random.PRNGKey(0), shape, jnp.float32)
        q = jnp.full((shape[0],), 1.0 - (1.0 - trim) * 0.5, jnp.float32)
        block_bytes = rows.size * rows.dtype.itemsize
        for contract, fn in (
                (q_ops.fused_quantile_contract(block_bytes, padded=padded),
                 fused),
                (q_ops.topk_tail_contract(block_bytes, padded=padded),
                 topk)):
            jaxpr = jax.make_jaxpr(fn)(rows, q)
            txt = jax.jit(fn).lower(rows, q).compile().as_text()
            out.append(contract.check(jaxpr=jaxpr, hlo=txt,
                                      row_elems=rows.size))

    # rows past the single-pass VMEM budget (_SINGLE_PASS_ELEMS) must take
    # the two-stage multilevel kernel: one row-sized read site, zero sorts
    long_rows = jax.random.normal(jax.random.PRNGKey(3),
                                  (2, (1 << 18) + 512), jnp.float32)
    ql = jnp.full((2,), 1.0 - (1.0 - trim) * 0.5, jnp.float32)

    def ml(rows, q):
        t, ss = q_ops.row_trimmed_stats(rows, q, use_kernel=True,
                                        interpret=True)
        return t, ss

    jaxpr = jax.make_jaxpr(ml)(long_rows, ql)
    txt = jax.jit(ml).lower(long_rows, ql).compile().as_text()
    out.append(q_ml.multilevel_quantile_contract(
        long_rows.size * long_rows.dtype.itemsize).check(
            jaxpr=jaxpr, hlo=txt, row_elems=long_rows.size))
    return out


def dist_quantile_report(mesh, m: int = 4, trim: float = 0.95) -> Report:
    """Lower the distributed trimmed-norm pass on the 2-D
    P("data", "model") cohort layout (the tentpole of ISSUE 9) and check
    ``distributed_quantile_contract``: each device reads only its local
    (m/D, N/n_model) slice (1 row read, 0 sorts), there are ZERO gathers
    or re-layout collectives, and every all-reduce is bounded by the
    histogram-plane payload — never O(N)."""
    import jax
    import jax.numpy as jnp
    from repro.core import flat
    from repro.kernels.fedfa_quantile import multilevel as q_ml
    from repro.sharding import cohort as csh

    cfg, fl, params, specs, batches = _fixture(m)
    index, _, mp, _, _ = _padded_inputs(cfg, fl, params, specs, batches,
                                        mesh)
    xm = jax.device_put(
        jax.random.normal(jax.random.PRNGKey(1), (mp, index.n_padded),
                          jnp.float32), csh.cohort_buffer_sharding(mesh))
    fracs = jax.device_put(
        jnp.full((mp, len(index.leaves)), 0.75, jnp.float32),
        csh.cohort_sharding(mesh))

    def norms(xm, fracs):
        return flat._cohort_norms(index, xm, fracs, trim, True, True, mesh)

    jaxpr = jax.make_jaxpr(norms)(xm, fracs)
    txt = jax.jit(norms).lower(xm, fracs).compile().as_text()
    local_rows = mp // csh.data_shards(mesh)
    slice_elems = local_rows * (index.n_padded // csh.model_shards(mesh))
    return q_ml.distributed_quantile_contract(
        local_rows, index.n_segments, slice_elems * 4).check(
            jaxpr=jaxpr, hlo=txt, row_elems=slice_elems)


def canonical_reports(progress: Callable[[str], None] = lambda s: None
                      ) -> List[Report]:
    """Every contract of the canonical program set, in table order.
    Requires >= 4 devices with both mesh axes available."""
    import jax
    from repro.launch.mesh import make_data_mesh, make_mesh_2d

    if jax.device_count() < 4:
        raise RuntimeError(
            f"the canonical check set needs >= 4 devices (got "
            f"{jax.device_count()}); run via `python -m repro.analysis "
            f"check`, which forces 4 host devices")
    mesh_1d = make_data_mesh()
    mesh_2d = make_mesh_2d(2, 2)
    reports: List[Report] = []
    for label, build in (
            ("round (data mesh)", lambda: round_report(mesh_1d)),
            ("round (2x2 mesh)", lambda: round_report(mesh_2d)),
            ("quantized round (data mesh)",
             lambda: quant_round_report(mesh_1d)),
            ("aggregation (data mesh)", lambda: agg_report(mesh_1d)),
            ("aggregation (2x2 mesh)", lambda: agg_report(mesh_2d)),
            ("async admit (data mesh)", lambda: admit_report(mesh_1d)),
            ("quantized admit (data mesh)",
             lambda: quant_admit_report(mesh_1d)),
            ("async merge (data mesh)", lambda: merge_report(mesh_1d)),
            ("async merge (2x2 mesh)", lambda: merge_report(mesh_2d)),
            ("quantile jaxpr", quantile_reports),
            ("distributed quantile (2x2 mesh)",
             lambda: dist_quantile_report(mesh_2d))):
        progress(f"lowering {label} ...")
        got = build()
        reports.extend(got if isinstance(got, list) else [got])
    return reports


def cache_checks() -> List[Tuple[str, List[str]]]:
    """The runtime-adjacent pass results for the check CLI: (pass name,
    violation messages) pairs — empty messages means PASS."""
    import jax
    from repro.analysis import passes
    from repro.core import flat
    from repro.core import round as round_mod
    from repro.launch.mesh import make_data_mesh, make_mesh_2d
    from repro.models import model as model_mod
    from repro.configs import get_arch
    from repro.core.server import FLConfig

    cfg = get_arch("smollm-135m").reduced().replace(
        n_layers=4, n_sections=2, vocab_size=64, tie_embeddings=False)
    params = model_mod.init_params(cfg, jax.random.PRNGKey(0))
    index = flat.get_index(params)
    fl = FLConfig(local_steps=1, lr=0.05, strategy="fedfa", task="cls",
                  agg_engine="flat")
    mesh_1d, mesh_2d = make_data_mesh(), make_mesh_2d(2, 2)

    # key discrimination: every mesh/pad/row-count variation must key a
    # DISTINCT compiled program (the PR 5/6 bug class)
    variants = [
        ("no mesh", round_mod._round_key(cfg, fl, index,
                                         any_malicious=False)),
        ("data mesh", round_mod._round_key(cfg, fl, index,
                                           any_malicious=False,
                                           mesh=mesh_1d)),
        ("2x2 mesh", round_mod._round_key(cfg, fl, index,
                                          any_malicious=False,
                                          mesh=mesh_2d)),
        ("data mesh, padded m=3", round_mod._round_key(
            cfg, fl, index, any_malicious=False, mesh=mesh_1d, m_real=3)),
        ("malicious", round_mod._round_key(cfg, fl, index,
                                           any_malicious=True)),
    ]
    # the PR 10 bug class: two configs differing ONLY in the cohort
    # admission dtype must compile (and cache) distinct programs — an
    # int8 pool fed to the f32 program is a shape error at best
    import dataclasses
    for dt in ("bf16", "int8"):
        variants.append((f"{dt} admission", round_mod._round_key(
            cfg, dataclasses.replace(fl, update_dtype=dt), index,
            any_malicious=False)))
    collisions = passes.check_cache_keys(variants)

    # retrace audit: a REBUILT identical mesh must hit the program cache,
    # not recompile (mesh keyed by value, not identity)
    with passes.RecompileAuditor() as aud:
        round_mod.make_flat_round(cfg, fl, index, any_malicious=False,
                                  mesh=make_data_mesh())
        round_mod.make_flat_round(cfg, fl, index, any_malicious=False,
                                  mesh=make_data_mesh())
    retrace = []
    if aud.inserts > 1:
        retrace.append(
            f"rebuilt-identical mesh recompiled the round program "
            f"({aud.report()}) — mesh keyed by identity, not value?")
    if aud.hits < 1:
        retrace.append(f"no cache hit on the second identical build "
                       f"({aud.report()})")
    return [("cache-key discrimination", collisions),
            ("recompile audit (rebuilt mesh)", retrace)]
