"""Attribute collectives to the Python source line that introduced them.

XLA threads JAX's source provenance through lowering as per-instruction
``metadata={op_name=... source_file=... source_line=...}``; ``analysis/hlo``
parses it onto each :class:`~repro.analysis.hlo.CollectiveOp`.  This module
turns those records into human-facing attributions so a contract violation
names the line of *our* code that made GSPMD emit the collective — the
difference between "admit has 2 unexplained all-gathers" (PR 7) and
"``c_buf.at[slots].set`` at async_round.py:191 re-gathers the pool" (this
PR's follow-up (a) fix).

Ops XLA synthesizes itself (resharding halves, fusion roots) carry no
metadata and render as ``(no provenance)`` — absence of blame is itself a
signal that GSPMD, not user code, chose the op.
"""
from __future__ import annotations

import os
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

from . import hlo


def short_op(op_name: Optional[str]) -> Optional[str]:
    """Last component of a jax op_name path (``jit(f)/jit(main)/a/b`` →
    ``b``) — the primitive that lowered to this op."""
    if not op_name:
        return None
    return op_name.rstrip("/").rsplit("/", 1)[-1]


def source_ref(op: hlo.CollectiveOp) -> Optional[str]:
    """``file.py:line`` (basename) for an op, None without provenance."""
    if not op.source_file:
        return None
    ref = os.path.basename(op.source_file)
    if op.source_line is not None:
        ref += f":{op.source_line}"
    return ref


def describe(op: hlo.CollectiveOp) -> str:
    """One-line attribution: ``all-gather[9708544] scatter
    (async_round.py:191)`` or ``... (no provenance)``."""
    size = f"[{op.elems}]" if op.elems is not None else ""
    prim = short_op(op.op_name)
    ref = source_ref(op)
    where = f"{prim} ({ref})" if prim and ref else (
        prim or ref or "(no provenance)")
    return f"{op.kind}{size} {where}"


@dataclass(frozen=True)
class BlameEntry:
    """Collectives grouped by (kind, source line): one row of the table."""
    kind: str
    source: Optional[str]   # "file.py:line" or None (no provenance)
    op_name: Optional[str]  # short primitive name of a representative op
    count: int
    max_elems: int
    total_elems: int


def blame_table(src: hlo.Source) -> List[BlameEntry]:
    """Collectives of a program grouped by provenance, largest first."""
    groups: Dict[Tuple[str, Optional[str]], List[hlo.CollectiveOp]] = {}
    for op in hlo._ops(src):
        groups.setdefault((op.kind, source_ref(op)), []).append(op)
    out = [
        BlameEntry(
            kind=kind, source=ref, op_name=short_op(ops[0].op_name),
            count=len(ops),
            max_elems=max((o.elems or 0) for o in ops),
            total_elems=sum((o.elems or 0) for o in ops))
        for (kind, ref), ops in groups.items()
    ]
    out.sort(key=lambda e: (-e.total_elems, e.kind, e.source or ""))
    return out


def format_blame(src: hlo.Source, kinds: Optional[Sequence[str]] = None,
                 limit: int = 8) -> List[str]:
    """Attribution lines for a violation message, optionally filtered to the
    offending collective kinds, biggest contributors first."""
    rows = [e for e in blame_table(src)
            if kinds is None or e.kind in kinds]
    lines = [
        f"{e.kind} x{e.count} (max {e.max_elems} elems) <- "
        f"{(e.op_name or '?')} at {e.source or '(no provenance)'}"
        for e in rows[:limit]
    ]
    if len(rows) > limit:
        lines.append(f"... and {len(rows) - limit} more blame rows")
    return lines
