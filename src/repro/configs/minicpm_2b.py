"""Selectable config module (--arch minicpm_2b)."""
from repro.configs.registry import MINICPM_2B as CONFIG

__all__ = ["CONFIG"]
