"""Selectable config module (--arch internvl2_76b)."""
from repro.configs.registry import INTERNVL2_76B as CONFIG

__all__ = ["CONFIG"]
