"""Architecture / run configuration for the repro framework.

Every assigned architecture is expressed as an :class:`ArchConfig`.  The
model stack (``repro.models``) consumes these declaratively; the FedFA core
(``repro.core``) derives width masks / depth maps from them.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    # Snowflake-Arctic style: a dense FFN residual branch in parallel with MoE.
    dense_residual: bool = False
    capacity_factor: float = 1.25
    router_z_loss: float = 1e-3
    load_balance_loss: float = 1e-2


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 SSD block configuration."""
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk: int = 128

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class RGLRUConfig:
    """RecurrentGemma RG-LRU block configuration."""
    d_conv: int = 4
    expand: float = 1.5          # d_rnn = expand * d_model (RG uses lru_width)
    c: float = 8.0               # a = a_param ** (c * r_t)

    def d_rnn(self, d_model: int) -> int:
        return int(self.expand * d_model)


@dataclass(frozen=True)
class EncoderConfig:
    """Encoder stack for enc-dec (whisper).  The conv/mel frontend is a stub:
    input_specs() provides precomputed frame embeddings (B, n_frames, d_model)."""
    n_layers: int = 6
    n_frames: int = 1500


@dataclass(frozen=True)
class VisionConfig:
    """VLM frontend stub: input_specs() provides precomputed patch embeddings
    (B, n_patches, vit_dim); a trainable MLP projector maps to d_model."""
    n_patches: int = 1024
    vit_dim: int = 3200


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    citation: str = ""

    d_head: int = 0                  # 0 -> d_model // n_heads
    max_seq_len: int = 524_288
    rope_theta: float = 10_000.0
    attn_window: Optional[int] = None       # sliding window; None = full
    # unit of block kinds; repeated (with truncation) to fill n_layers.
    layer_pattern: Tuple[str, ...] = ("attn",)
    act: str = "silu"
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    logit_softcap: Optional[float] = None

    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    rglru: Optional[RGLRUConfig] = None
    encoder: Optional[EncoderConfig] = None
    vision: Optional[VisionConfig] = None

    # --- FedFA ---
    n_sections: int = 4              # contiguous groups of scan repeats

    # Pad embedding/lm-head rows to a multiple of 128 so the vocab dim
    # shards over the 16-way model axis (odd vocabs like 122753 otherwise
    # force replicated logits — 605 GB/device at train_4k; see
    # EXPERIMENTS.md §Perf).  Padded logits are masked to -inf in _head.
    pad_vocab: bool = True

    # --- runtime / distribution policy ---
    dtype: str = "bfloat16"
    fsdp: bool = False               # additionally shard params over 'data'
    # serving keeps weights model-sharded only (no per-token all-gather)
    # unless they don't fit 16 GB/chip that way (arctic, internvl2).
    serve_fsdp: bool = False
    remat: bool = True
    grad_accum: int = 1              # microbatches per train step
    optimizer: str = "sgd"           # sgd | adamw (paper uses SGD+momentum)
    momentum_dtype: str = "float32"  # bfloat16 halves optimizer HBM (arctic)
    learning_rate: float = 0.01
    momentum: float = 0.9
    weight_decay: float = 1e-4
    schedule: str = "constant"       # constant | step | wsd | cosine
    # long_500k handling: 'window' (sliding-window variant), 'native'
    # (ssm/hybrid state decode), or 'skip'.
    long_context_mode: str = "window"
    # chunked prefill: process the prompt in chunks of this many positions
    # against the growing KV cache (bounds MoE dispatch buffers, which are
    # token-count proportional and GSPMD-replicated). None = single shot.
    prefill_chunk: Optional[int] = None

    # ----- derived -----
    @property
    def padded_vocab(self) -> int:
        if not self.pad_vocab:
            return self.vocab_size
        return (self.vocab_size + 127) // 128 * 128

    @property
    def head_dim(self) -> int:
        return self.d_head or (self.d_model // max(self.n_heads, 1))

    @property
    def pattern_unit(self) -> Tuple[str, ...]:
        return self.layer_pattern

    def stages(self) -> Tuple[Tuple[Tuple[str, ...], int], ...]:
        """Decompose n_layers into scan stages: [(pattern_unit, n_repeats)].

        Full repeats of the pattern unit form one scanned stage; a remainder
        (n_layers % len(unit) != 0) forms a second stage with a truncated unit.
        """
        unit = self.pattern_unit
        k = len(unit)
        full, rem = divmod(self.n_layers, k)
        out = []
        if full:
            out.append((unit, full))
        if rem:
            out.append((unit[:rem], 1))
        return tuple(out)

    @property
    def n_repeats(self) -> int:
        """Total scan repeats across stages (units of depth flexibility)."""
        return sum(r for _, r in self.stages())

    def section_bounds(self) -> Tuple[Tuple[int, int], ...]:
        """FedFA sections over the repeat axis of stage 0 (the main stack)."""
        reps = self.stages()[0][1]
        n_sec = min(self.n_sections, reps)
        base, extra = divmod(reps, n_sec)
        bounds, start = [], 0
        for s in range(n_sec):
            size = base + (1 if s < extra else 0)
            bounds.append((start, start + size))
            start += size
        return tuple(bounds)

    def param_count(self) -> int:
        """Approximate parameter count (embedding + blocks + head)."""
        D, F, V = self.d_model, self.d_ff, self.vocab_size
        H, K, hd = self.n_heads, self.n_kv_heads, self.head_dim
        per: dict = {}
        per["attn"] = D * (H * hd) + 2 * D * (K * hd) + (H * hd) * D + 2 * D
        per["mlp"] = 3 * D * F + 2 * D
        if self.moe:
            e = self.moe
            per["moe"] = (e.n_experts * 3 * D * e.d_ff_expert + D * e.n_experts
                          + (3 * D * F if e.dense_residual else 0) + 2 * D)
        if self.ssm:
            s = self.ssm
            di = s.d_inner(D)
            per["ssd"] = (D * (2 * di + 2 * s.d_state * 0 + s.n_heads(D))
                          + di * (2 * s.d_state) + s.d_conv * di + di * D + 2 * D)
        if self.rglru:
            r = self.rglru
            dr = r.d_rnn(D)
            per["rglru"] = D * dr * 2 + r.d_conv * dr + 3 * dr + dr * D + 2 * D
        total = 0
        for unit, reps in self.stages():
            for kind in unit:
                blk = {"attn": per["attn"] + per.get("moe", per["mlp"]) if self.moe
                       else per["attn"] + per["mlp"],
                       "ssd": per.get("ssd", 0),
                       "rglru": per.get("rglru", 0) + per["mlp"]}[kind]
                total += blk * reps
        total += V * D * (1 if self.tie_embeddings else 2) + D
        if self.vision:
            total += self.vision.vit_dim * D + D * D
        if self.encoder:
            enc_blk = per["attn"] + per["mlp"]
            total += self.encoder.n_layers * (enc_blk + per["attn"])  # +cross-attn in dec counted roughly
        return total

    def active_param_count(self) -> int:
        """Per-token active params (MoE: top_k experts instead of all)."""
        if not self.moe:
            return self.param_count()
        e = self.moe
        all_expert = self.n_repeats_total_layers() * e.n_experts * 3 * self.d_model * e.d_ff_expert
        act_expert = self.n_repeats_total_layers() * e.top_k * 3 * self.d_model * e.d_ff_expert
        return self.param_count() - all_expert + act_expert

    def n_repeats_total_layers(self) -> int:
        return self.n_layers

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    def reduced(self) -> "ArchConfig":
        """Smoke-test variant: <=2 layers, d_model<=512, <=4 experts."""
        kw = dict(
            n_layers=min(self.n_layers, 2 * len(self.pattern_unit)),
            d_model=min(self.d_model, 256),
            n_heads=min(self.n_heads, 4),
            n_kv_heads=min(self.n_kv_heads, 2),
            d_ff=min(self.d_ff, 512),
            vocab_size=min(self.vocab_size, 512),
            d_head=64 if self.d_head else 0,
            max_seq_len=512,
            n_sections=2,
            grad_accum=1,
            fsdp=False,
        )
        if self.moe:
            kw["moe"] = dataclasses.replace(
                self.moe, n_experts=min(self.moe.n_experts, 4),
                d_ff_expert=min(self.moe.d_ff_expert, 256))
        if self.ssm:
            kw["ssm"] = dataclasses.replace(self.ssm, d_state=32, head_dim=32, chunk=32)
        if self.encoder:
            kw["encoder"] = dataclasses.replace(self.encoder, n_layers=2, n_frames=64)
        if self.vision:
            kw["vision"] = dataclasses.replace(self.vision, n_patches=16, vit_dim=128)
        if self.attn_window:
            kw["attn_window"] = min(self.attn_window, 128)
        return self.replace(**kw)


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode


TRAIN_4K = InputShape("train_4k", 4_096, 256, "train")
PREFILL_32K = InputShape("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = InputShape("decode_32k", 32_768, 128, "decode")
LONG_500K = InputShape("long_500k", 524_288, 1, "decode")

INPUT_SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}
