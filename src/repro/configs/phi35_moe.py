"""Selectable config module (--arch phi35_moe)."""
from repro.configs.registry import PHI35_MOE as CONFIG

__all__ = ["CONFIG"]
