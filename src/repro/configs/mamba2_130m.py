"""Selectable config module (--arch mamba2_130m)."""
from repro.configs.registry import MAMBA2_130M as CONFIG

__all__ = ["CONFIG"]
