from repro.configs.base import (ArchConfig, InputShape, INPUT_SHAPES, MoEConfig,
                                SSMConfig, RGLRUConfig, EncoderConfig, VisionConfig,
                                TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
from repro.configs.registry import ARCHS, ASSIGNED, get_arch
