"""Registry of assigned architectures (+ the paper's own transformer)."""
from __future__ import annotations

from repro.configs.base import (ArchConfig, EncoderConfig, MoEConfig,
                                RGLRUConfig, SSMConfig, VisionConfig)

# --------------------------------------------------------------------------
# Assigned architectures (public-literature pool; citations in brackets).
# --------------------------------------------------------------------------

MINICPM_2B = ArchConfig(
    name="minicpm-2b", family="dense", citation="arXiv:2404.06395",
    n_layers=40, d_model=2304, n_heads=36, n_kv_heads=36, d_ff=5760,
    vocab_size=122_753, d_head=64, tie_embeddings=True,
    schedule="wsd", optimizer="adamw", learning_rate=1e-2,
    fsdp=True, grad_accum=4,
)

SMOLLM_135M = ArchConfig(
    name="smollm-135m", family="dense", citation="hf:HuggingFaceTB/SmolLM-135M",
    n_layers=30, d_model=576, n_heads=9, n_kv_heads=3, d_ff=1536,
    vocab_size=49_152, d_head=64, tie_embeddings=True,
)

ARCTIC_480B = ArchConfig(
    name="arctic-480b", family="moe", citation="hf:Snowflake/snowflake-arctic-base",
    n_layers=35, d_model=7168, n_heads=56, n_kv_heads=8, d_ff=4864,
    vocab_size=32_000, d_head=128,
    moe=MoEConfig(n_experts=128, top_k=2, d_ff_expert=4864, dense_residual=True),
    fsdp=True, serve_fsdp=True, grad_accum=128, optimizer="sgd",
    prefill_chunk=2048,
)

RECURRENTGEMMA_2B = ArchConfig(
    name="recurrentgemma-2b", family="hybrid", citation="arXiv:2402.19427",
    n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1, d_ff=7680,
    vocab_size=256_000, d_head=256, attn_window=2048,
    layer_pattern=("rglru", "rglru", "attn"),
    rglru=RGLRUConfig(expand=1.0),          # RG-2B lru_width == d_model (2560)
    act="gelu", logit_softcap=30.0, fsdp=True, grad_accum=4,
    long_context_mode="native",
)

MAMBA2_130M = ArchConfig(
    name="mamba2-130m", family="ssm", citation="arXiv:2405.21060",
    n_layers=24, d_model=768, n_heads=0, n_kv_heads=0, d_ff=0,
    vocab_size=50_280, layer_pattern=("ssd",),
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, chunk=128),
    tie_embeddings=True, norm="rmsnorm",
    long_context_mode="native",
)

TINYLLAMA_1B = ArchConfig(
    name="tinyllama-1.1b", family="dense", citation="arXiv:2401.02385",
    n_layers=22, d_model=2048, n_heads=32, n_kv_heads=4, d_ff=5632,
    vocab_size=32_000, d_head=64,
    fsdp=True,        # replicated fp32 momentum alone breaks 16 GB at train_4k
    grad_accum=2,     # halves live activations: 17.3 -> 8.9 GB true peak
)

PHI35_MOE = ArchConfig(
    name="phi3.5-moe-42b-a6.6b", family="moe", citation="hf:microsoft/Phi-3.5-MoE-instruct",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=6400,
    vocab_size=32_064, d_head=128,
    moe=MoEConfig(n_experts=16, top_k=2, d_ff_expert=6400),
    fsdp=True, grad_accum=8, prefill_chunk=1024,
)

INTERNVL2_76B = ArchConfig(
    name="internvl2-76b", family="vlm", citation="arXiv:2404.16821",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=28672,
    vocab_size=128_256, d_head=128,
    vision=VisionConfig(n_patches=1024, vit_dim=3200),
    fsdp=True, serve_fsdp=True, grad_accum=16,  # microbatch 16 = data axis;
    # A=32 would leave 8-seq microbatches unshardable (measured 7x worse)
)

CODEQWEN_7B = ArchConfig(
    name="codeqwen1.5-7b", family="dense", citation="hf:Qwen/CodeQwen1.5-7B",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=32, d_ff=13440,
    vocab_size=92_416, d_head=128, fsdp=True, grad_accum=4,
)

WHISPER_BASE = ArchConfig(
    name="whisper-base", family="audio", citation="arXiv:2212.04356",
    n_layers=6, d_model=512, n_heads=8, n_kv_heads=8, d_ff=2048,
    vocab_size=51_865, d_head=64, norm="layernorm", act="gelu",
    encoder=EncoderConfig(n_layers=6, n_frames=1500),
    rope_theta=0.0,                  # whisper uses learned/sinusoidal positions
    grad_accum=4,                    # cross-attention activations at B=256
    # whisper's natural target length is 448; the assigned decode shapes
    # exercise the backbone at 32k, so the learned position table is sized up
    # for the dry-run (DESIGN.md §Arch-applicability).
    max_seq_len=65_536,
    long_context_mode="skip",        # enc-dec ASR: 524k-token decode is not meaningful
)

# The paper's own Transformer LM (Table 4 rightmost column, WikiText-2):
# d_model=192, d_head=64, d_ff from the [3x3,64]x2-analog -> small FFN.
FEDFA_PAPER_TRANSFORMER = ArchConfig(
    name="fedfa-paper-transformer", family="dense", citation="FedFA Table 4",
    n_layers=4, d_model=192, n_heads=3, n_kv_heads=3, d_ff=768,
    vocab_size=28_782, d_head=64, max_seq_len=512, n_sections=1,
    optimizer="sgd", learning_rate=0.1, weight_decay=0.0,
)

ARCHS = {
    a.name: a for a in (
        MINICPM_2B, SMOLLM_135M, ARCTIC_480B, RECURRENTGEMMA_2B, MAMBA2_130M,
        TINYLLAMA_1B, PHI35_MOE, INTERNVL2_76B, CODEQWEN_7B, WHISPER_BASE,
        FEDFA_PAPER_TRANSFORMER,
    )
}

ASSIGNED = [a for a in ARCHS if a != "fedfa-paper-transformer"]


def get_arch(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]
