"""Selectable config module (--arch arctic_480b)."""
from repro.configs.registry import ARCTIC_480B as CONFIG

__all__ = ["CONFIG"]
