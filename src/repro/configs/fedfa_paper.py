"""Selectable config module (--arch fedfa_paper)."""
from repro.configs.registry import FEDFA_PAPER_TRANSFORMER as CONFIG

__all__ = ["CONFIG"]
