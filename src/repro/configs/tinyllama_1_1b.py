"""Selectable config module (--arch tinyllama_1_1b)."""
from repro.configs.registry import TINYLLAMA_1B as CONFIG

__all__ = ["CONFIG"]
