"""Selectable config module (--arch recurrentgemma_2b)."""
from repro.configs.registry import RECURRENTGEMMA_2B as CONFIG

__all__ = ["CONFIG"]
