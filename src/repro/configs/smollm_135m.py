"""Selectable config module (--arch smollm_135m)."""
from repro.configs.registry import SMOLLM_135M as CONFIG

__all__ = ["CONFIG"]
