"""Selectable config module (--arch codeqwen1_5_7b)."""
from repro.configs.registry import CODEQWEN_7B as CONFIG

__all__ = ["CONFIG"]
