"""Selectable config module (--arch whisper_base)."""
from repro.configs.registry import WHISPER_BASE as CONFIG

__all__ = ["CONFIG"]
