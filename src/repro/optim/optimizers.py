"""Optimizers: SGD+momentum (paper's choice, Table 6) and AdamW.

Minimal optax-free implementations so the whole substrate is self-contained.
State layout: {"step": (), "m": tree [, "v": tree]}.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

OptState = Dict[str, Any]


def init_opt(params, name: str, momentum_dtype=jnp.float32) -> OptState:
    z = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=momentum_dtype), params)
    st: OptState = {"step": jnp.zeros((), jnp.int32), "m": z}
    if name == "adamw":
        st["v"] = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    return st


def sgd_momentum(params, grads, st: OptState, lr, *, momentum=0.9,
                 weight_decay=1e-4) -> Tuple[Any, OptState]:
    g_eff = jax.tree.map(
        lambda p, g: g.astype(jnp.float32) + weight_decay * p.astype(jnp.float32),
        params, grads)
    new_m = jax.tree.map(lambda m, g: momentum * m + g, st["m"], g_eff)
    new_p = jax.tree.map(
        lambda p, m: (p.astype(jnp.float32) - lr * m).astype(p.dtype),
        params, new_m)
    return new_p, {"step": st["step"] + 1, "m": new_m}


def adamw(params, grads, st: OptState, lr, *, b1=0.9, b2=0.95, eps=1e-8,
          weight_decay=0.1) -> Tuple[Any, OptState]:
    step = st["step"] + 1
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)
    new_m = jax.tree.map(
        lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32), st["m"], grads)
    new_v = jax.tree.map(
        lambda v, g: b2 * v + (1 - b2) * g.astype(jnp.float32) ** 2, st["v"], grads)
    new_p = jax.tree.map(
        lambda p, m, v: (p.astype(jnp.float32) - lr * (
            (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            + weight_decay * p.astype(jnp.float32))).astype(p.dtype),
        params, new_m, new_v)
    return new_p, {"step": step, "m": new_m, "v": new_v}


def opt_update(name: str, params, grads, st: OptState, lr, **kw):
    if name == "sgd":
        kw.setdefault("momentum", 0.9)
        kw.setdefault("weight_decay", 1e-4)
        return sgd_momentum(params, grads, st, lr, **kw)
    if name == "adamw":
        return adamw(params, grads, st, lr, **kw)
    raise ValueError(name)
