"""LR schedules: constant, step decay (paper Table 6), cosine, and WSD
(warmup-stable-decay; MiniCPM's schedule, cited for the minicpm-2b config)."""
from __future__ import annotations

import jax.numpy as jnp


def make_schedule(name: str, base_lr: float, total_steps: int, *,
                  warmup: int = 0, decay_at=(0.5, 0.75), decay_factor=0.1,
                  stable_frac: float = 0.8):
    total = max(total_steps, 1)

    def constant(step):
        return jnp.full((), base_lr, jnp.float32)

    def step_decay(step):
        lr = jnp.full((), base_lr, jnp.float32)
        for frac in decay_at:
            lr = jnp.where(step >= frac * total, lr * decay_factor, lr)
        return lr

    def cosine(step):
        warm = jnp.minimum(step / jnp.maximum(warmup, 1), 1.0)
        prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0, 1)
        return base_lr * warm * 0.5 * (1 + jnp.cos(jnp.pi * prog))

    def wsd(step):
        """Warmup -> stable plateau -> 1-sqrt decay tail (MiniCPM)."""
        warm = jnp.minimum(step / jnp.maximum(warmup, 1), 1.0)
        stable_end = stable_frac * total
        tail = jnp.clip((step - stable_end) / jnp.maximum(total - stable_end, 1), 0, 1)
        return base_lr * warm * (1.0 - (1.0 - 0.1) * jnp.sqrt(tail))

    return {"constant": constant, "step": step_decay,
            "cosine": cosine, "wsd": wsd}[name]
