from repro.optim.optimizers import (OptState, init_opt, opt_update,
                                    sgd_momentum, adamw)
from repro.optim.schedules import make_schedule
