from repro.models import model, masks, attention, transformer
