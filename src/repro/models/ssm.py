"""Mamba-2 SSD (state-space duality) block, chunked for TPUs.

Training/prefill uses the chunked SSD algorithm (intra-chunk quadratic
"attention-like" term + inter-chunk recurrent state carry via lax.scan);
decode is the exact single-step recurrence on an (n_heads, head_dim,
d_state) state — this is what makes ``long_500k`` native for mamba2.

The intra-chunk term is the compute hot-spot and has a Pallas kernel in
``repro.kernels.ssd`` validated against the jnp path here.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import SSMConfig
from repro.models.layers import dense_init, rms_norm


class SSMCache(NamedTuple):
    conv: jax.Array      # (B, d_conv-1, conv_dim) last inputs to the conv
    h: jax.Array         # (B, nh, hp, N) recurrent state
    pos: jax.Array       # () int32


def init_ssd(key, d_model: int, cfg: SSMConfig, dtype) -> dict:
    di = cfg.d_inner(d_model)
    nh = cfg.n_heads(d_model)
    N = cfg.d_state
    conv_dim = di + 2 * N
    ks = jax.random.split(key, 4)
    return {
        "in_proj": dense_init(ks[0], (d_model, 2 * di + 2 * N + nh), dtype),
        "conv_w": dense_init(ks[1], (cfg.d_conv, conv_dim), dtype, scale=3.0),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.zeros((nh,), jnp.float32),          # A = -exp(A_log) = -1
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "norm": jnp.zeros((di,), dtype),
        "out_proj": dense_init(ks[3], (di, d_model), dtype),
    }


def _split_proj(proj: jax.Array, di: int, N: int, nh: int):
    z, xBC, dt = jnp.split(proj, [di, di + di + 2 * N], axis=-1)
    return z, xBC, dt


def _causal_conv(xBC: jax.Array, w: jax.Array, b: jax.Array,
                 state: Optional[jax.Array] = None):
    """Depthwise causal conv over (B, S, C). Returns (out, new_state)."""
    K = w.shape[0]
    if state is None:
        pad = jnp.zeros(xBC.shape[:1] + (K - 1,) + xBC.shape[2:], xBC.dtype)
    else:
        pad = state.astype(xBC.dtype)
    xp = jnp.concatenate([pad, xBC], axis=1)                 # (B, S+K-1, C)
    out = sum(xp[:, i:i + xBC.shape[1]] * w[i][None, None] for i in range(K))
    out = jax.nn.silu(out + b[None, None])
    new_state = xp[:, -(K - 1):] if K > 1 else pad[:, :0]
    return out, new_state


def ssd_chunked_ref(x, dt, A, B, C, chunk: int):
    """Pure-jnp chunked SSD.  Shapes:
      x: (b, S, nh, hp); dt: (b, S, nh) post-softplus; A: (nh,) negative;
      B, C: (b, S, N) (ngroups=1 shared over heads).
    Returns y: (b, S, nh, hp) and final state (b, nh, hp, N).
    """
    b, S, nh, hp = x.shape
    N = B.shape[-1]
    Q = chunk
    pad = (-S) % Q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    Sp = S + pad
    nc = Sp // Q
    xc = x.reshape(b, nc, Q, nh, hp)
    dtc = dt.reshape(b, nc, Q, nh).astype(jnp.float32)
    Bc = B.reshape(b, nc, Q, N).astype(jnp.float32)
    Cc = C.reshape(b, nc, Q, N).astype(jnp.float32)

    la = dtc * A[None, None, None, :]                     # log a_t  (b,nc,Q,nh)
    L = jnp.cumsum(la, axis=2)                            # cumulative within chunk

    # intra-chunk: M[t,s] = (C_t . B_s) * exp(L_t - L_s) * dt_s  for s <= t
    CB = jnp.einsum("bctn,bcsn->bcts", Cc, Bc)            # (b,nc,Q,Q)
    diff = L[:, :, :, None, :] - L[:, :, None, :, :]      # (b,nc,t,s,nh)
    causal = jnp.tril(jnp.ones((Q, Q), bool))
    M = CB[..., None] * jnp.exp(jnp.where(causal[None, None, :, :, None], diff, -jnp.inf))
    M = M * dtc[:, :, None, :, :]                         # weight by dt_s
    y_intra = jnp.einsum("bctsh,bcshp->bcthp", M, xc.astype(jnp.float32))

    # inter-chunk state carry
    # state contribution of chunk c: sum_s exp(L_Q - L_s) dt_s B_s x_s^T
    decay_to_end = jnp.exp(L[:, :, -1:, :] - L)           # (b,nc,Q,nh)
    dB = Bc[:, :, :, None, :] * (dtc * decay_to_end)[..., None]   # (b,nc,Q,nh,N)
    chunk_state = jnp.einsum("bcshn,bcshp->bchpn", dB[:, :, :, :, :], xc.astype(jnp.float32))
    chunk_decay = jnp.exp(L[:, :, -1, :])                 # (b,nc,nh)

    def step(h, inp):
        st, dec, Lc, Cck = inp
        # y_inter[t] = exp(L_t) * C_t @ h
        y_int = jnp.einsum("btn,bhpn,bth->bthp", Cck, h, jnp.exp(Lc))
        h_next = dec[:, :, None, None] * h + st
        return h_next, y_int

    h0 = jnp.zeros((b, nh, hp, N), jnp.float32)
    # scan over chunks
    hF, y_inter = jax.lax.scan(
        step, h0,
        (jnp.moveaxis(chunk_state, 1, 0), jnp.moveaxis(chunk_decay, 1, 0),
         jnp.moveaxis(L, 1, 0), jnp.moveaxis(Cc, 1, 0)))
    y_inter = jnp.moveaxis(y_inter, 0, 1)                 # (b,nc,Q,nh,hp)

    y = (y_intra + y_inter).reshape(b, Sp, nh, hp)[:, :S]
    return y.astype(x.dtype), hF


def ssd_forward(params: dict, u: jax.Array, cfg: SSMConfig, d_model: int,
                head_mask: Optional[jax.Array] = None,
                d_model_mask: Optional[jax.Array] = None,
                norm_eps: float = 1e-5,
                cache: Optional[SSMCache] = None,
                use_kernel: bool = False):
    """Full-sequence SSD block. u: (B, S, D). Returns (out, new_cache|None)."""
    di = cfg.d_inner(d_model)
    nh = cfg.n_heads(d_model)
    hp, N = cfg.head_dim, cfg.d_state
    proj = u @ params["in_proj"]
    z, xBC, dt_raw = _split_proj(proj, di, N, nh)
    xBC, conv_state = _causal_conv(xBC, params["conv_w"], params["conv_b"],
                                   None if cache is None else cache.conv)
    x, B, C = jnp.split(xBC, [di, di + N], axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])
    xh = x.reshape(*x.shape[:2], nh, hp)
    if head_mask is not None:
        xh = xh * head_mask[None, None, :, None].astype(xh.dtype)
        dt = dt * head_mask[None, None, :]
    if use_kernel:
        from repro.kernels.ssd import ops as ssd_ops
        y, hF = ssd_ops.ssd(xh, dt, A, B, C, cfg.chunk)
    else:
        y, hF = ssd_chunked_ref(xh, dt, A, B, C, cfg.chunk)
    y = y + (params["D"][None, None, :, None] * xh.astype(jnp.float32)).astype(y.dtype)
    y = y.reshape(*y.shape[:2], di)
    inner_mask = None
    if head_mask is not None:
        inner_mask = jnp.repeat(head_mask, hp)
    y = rms_norm(y * jax.nn.silu(z), params["norm"], inner_mask, norm_eps)
    out = y @ params["out_proj"]
    if d_model_mask is not None:
        out = out * d_model_mask.astype(out.dtype)
    new_cache = None
    if cache is not None:
        new_cache = SSMCache(conv_state, hF, cache.pos + u.shape[1])
    return out, new_cache


def ssd_decode(params: dict, u: jax.Array, cfg: SSMConfig, d_model: int,
               cache: SSMCache,
               head_mask: Optional[jax.Array] = None,
               d_model_mask: Optional[jax.Array] = None,
               norm_eps: float = 1e-5):
    """Single-token recurrence. u: (B, 1, D)."""
    di = cfg.d_inner(d_model)
    nh = cfg.n_heads(d_model)
    hp, N = cfg.head_dim, cfg.d_state
    proj = u @ params["in_proj"]
    z, xBC, dt_raw = _split_proj(proj, di, N, nh)
    # conv over the stored window + this input
    K = params["conv_w"].shape[0]
    xp = jnp.concatenate([cache.conv.astype(xBC.dtype), xBC], axis=1)  # (B,K,C)
    out = jnp.einsum("bkc,kc->bc", xp, params["conv_w"]) + params["conv_b"]
    xBC1 = jax.nn.silu(out)[:, None]                      # (B,1,C)
    new_conv = xp[:, 1:]
    x, B, C = jnp.split(xBC1, [di, di + N], axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])[:, 0]  # (B,nh)
    A = -jnp.exp(params["A_log"])
    xh = x.reshape(x.shape[0], nh, hp).astype(jnp.float32)
    if head_mask is not None:
        xh = xh * head_mask[None, :, None]
        dt = dt * head_mask[None, :]
    a = jnp.exp(dt * A[None, :])                          # (B,nh)
    Bv = B[:, 0].astype(jnp.float32)                      # (B,N)
    Cv = C[:, 0].astype(jnp.float32)
    h = cache.h * a[:, :, None, None] + (
        (dt[:, :, None] * xh)[..., None] * Bv[:, None, None, :])
    y = jnp.einsum("bhpn,bn->bhp", h, Cv) + params["D"][None, :, None] * xh
    y = y.reshape(y.shape[0], 1, di).astype(u.dtype)
    inner_mask = jnp.repeat(head_mask, hp) if head_mask is not None else None
    y = rms_norm(y * jax.nn.silu(z), params["norm"], inner_mask, norm_eps)
    outp = y @ params["out_proj"]
    if d_model_mask is not None:
        outp = outp * d_model_mask.astype(outp.dtype)
    return outp, SSMCache(new_conv, h, cache.pos + 1)


def init_ssm_cache(batch: int, d_model: int, cfg: SSMConfig, dtype) -> SSMCache:
    di = cfg.d_inner(d_model)
    nh = cfg.n_heads(d_model)
    conv_dim = di + 2 * cfg.d_state
    return SSMCache(
        conv=jnp.zeros((batch, cfg.d_conv - 1, conv_dim), dtype),
        h=jnp.zeros((batch, nh, cfg.head_dim, cfg.d_state), jnp.float32),
        pos=jnp.zeros((), jnp.int32))
