"""RecurrentGemma RG-LRU recurrent block (Real-Gated Linear Recurrent Unit).

Sequence mode uses an associative scan (log-depth, sub-quadratic); decode
is the exact one-step recurrence on a (B, d_rnn) state, which is what makes
``long_500k`` native for the hybrid architecture.

Block layout (De et al., arXiv:2402.19427):
  x -> [linear -> causal conv1d -> RG-LRU] * gelu(linear gate) -> linear out
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import RGLRUConfig
from repro.models.layers import dense_init


class RGLRUCache(NamedTuple):
    conv: jax.Array       # (B, d_conv-1, dr)
    h: jax.Array          # (B, dr) recurrent state
    pos: jax.Array


def init_rglru(key, d_model: int, cfg: RGLRUConfig, dtype) -> dict:
    dr = cfg.d_rnn(d_model)
    ks = jax.random.split(key, 6)
    # Lambda init so that a = sigmoid(Lambda)^c is in (0.9, 0.999)
    u = jax.random.uniform(ks[5], (dr,), jnp.float32, 0.9, 0.999)
    lam = jnp.log((u ** (1.0 / cfg.c)) / (1.0 - u ** (1.0 / cfg.c)))
    return {
        "in_x": dense_init(ks[0], (d_model, dr), dtype),
        "in_gate": dense_init(ks[1], (d_model, dr), dtype),
        "conv_w": dense_init(ks[2], (cfg.d_conv, dr), dtype, scale=3.0),
        "conv_b": jnp.zeros((dr,), dtype),
        "w_r": dense_init(ks[3], (dr, dr), jnp.float32),
        "b_r": jnp.zeros((dr,), jnp.float32),
        "w_i": dense_init(ks[4], (dr, dr), jnp.float32),
        "b_i": jnp.zeros((dr,), jnp.float32),
        "lam": lam,
        "out": dense_init(ks[0], (dr, d_model), dtype),
    }


def _gates(params, x, mask, c):
    """r,i gates and log-decay. x: (..., dr) float32."""
    r = jax.nn.sigmoid(x @ params["w_r"] + params["b_r"])
    i = jax.nn.sigmoid(x @ params["w_i"] + params["b_i"])
    log_a_base = jax.nn.log_sigmoid(params["lam"])         # log sigmoid(Lam)
    log_a = c * r * log_a_base[None]                       # broadcast (..., dr)
    if mask is not None:
        log_a = log_a * mask
        i = i * mask
    return log_a, i


def rglru_scan(params: dict, xin: jax.Array, cfg: RGLRUConfig,
               mask: Optional[jax.Array], h0: Optional[jax.Array] = None):
    """RG-LRU over a sequence via associative scan. xin: (B, S, dr) conv out."""
    xf = xin.astype(jnp.float32)
    log_a, i = _gates(params, xf, mask, cfg.c)             # (B,S,dr)
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-9))
    v = beta * (i * xf)                                    # input injection
    if h0 is not None:
        # fold initial state in as a virtual first step with a=carry
        v = v.at[:, 0].add(a[:, 0] * h0)
        # note: exact because h_1 = a_1 h_0 + v_1

    def combine(c1, c2):
        a1, v1 = c1
        a2, v2 = c2
        return a1 * a2, a2 * v1 + v2

    A, H = jax.lax.associative_scan(combine, (a, v), axis=1)
    if mask is not None:
        H = H * mask
    return H.astype(xin.dtype), H[:, -1]


def rglru_block(params: dict, u: jax.Array, cfg: RGLRUConfig, d_model: int,
                mask_dr: Optional[jax.Array] = None,
                d_model_mask: Optional[jax.Array] = None,
                cache: Optional[RGLRUCache] = None):
    """Full RG block over (B, S, D)."""
    x = u @ params["in_x"]
    gate = jax.nn.gelu(u @ params["in_gate"])
    if mask_dr is not None:
        x = x * mask_dr.astype(x.dtype)
        gate = gate * mask_dr.astype(gate.dtype)
    # causal depthwise conv
    K = params["conv_w"].shape[0]
    if cache is None:
        pad = jnp.zeros(x.shape[:1] + (K - 1,) + x.shape[2:], x.dtype)
    else:
        pad = cache.conv.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    xc = sum(xp[:, i:i + x.shape[1]] * params["conv_w"][i][None, None]
             for i in range(K)) + params["conv_b"][None, None]
    new_conv = xp[:, -(K - 1):]
    h0 = None if cache is None else cache.h
    y, hF = rglru_scan(params, xc, cfg, mask_dr, h0)
    out = (y * gate) @ params["out"]
    if d_model_mask is not None:
        out = out * d_model_mask.astype(out.dtype)
    new_cache = None
    if cache is not None:
        new_cache = RGLRUCache(new_conv, hF, cache.pos + u.shape[1])
    return out, new_cache


def rglru_decode(params: dict, u: jax.Array, cfg: RGLRUConfig, d_model: int,
                 cache: RGLRUCache,
                 mask_dr: Optional[jax.Array] = None,
                 d_model_mask: Optional[jax.Array] = None):
    """One-token step. u: (B, 1, D)."""
    x = (u @ params["in_x"])[:, 0]
    gate = jax.nn.gelu(u @ params["in_gate"])[:, 0]
    if mask_dr is not None:
        x = x * mask_dr.astype(x.dtype)
        gate = gate * mask_dr.astype(gate.dtype)
    K = params["conv_w"].shape[0]
    xp = jnp.concatenate([cache.conv.astype(x.dtype), x[:, None]], axis=1)
    xc = jnp.einsum("bkc,kc->bc", xp, params["conv_w"]) + params["conv_b"]
    new_conv = xp[:, 1:]
    xf = xc.astype(jnp.float32)
    log_a, i = _gates(params, xf, mask_dr, cfg.c)
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-9))
    h = a * cache.h + beta * (i * xf)
    if mask_dr is not None:
        h = h * mask_dr
    out = ((h.astype(u.dtype) * gate) @ params["out"])[:, None]
    if d_model_mask is not None:
        out = out * d_model_mask.astype(out.dtype)
    return out, RGLRUCache(new_conv, h, cache.pos + 1)


def init_rglru_cache(batch: int, d_model: int, cfg: RGLRUConfig, dtype) -> RGLRUCache:
    dr = cfg.d_rnn(d_model)
    return RGLRUCache(
        conv=jnp.zeros((batch, cfg.d_conv - 1, dr), dtype),
        h=jnp.zeros((batch, dr), jnp.float32),
        pos=jnp.zeros((), jnp.int32))
