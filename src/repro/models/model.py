"""Top-level model API: init / train forward / loss / prefill / decode.

All entry points take a ``ClientArch``-derived runtime (width masks + depth
gates); the full/global model is just the runtime with all-ones masks.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as attn_mod
from repro.models import rglru as rglru_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import apply_norm, softcap
from repro.models.masks import WidthMasks, full_masks, max_section_depths
from repro.models.transformer import (_stage_apply, init_params)

Params = Dict[str, Any]


def _full_gates(cfg: ArchConfig):
    return [jnp.ones((reps,), jnp.float32) for _, reps in cfg.stages()]


def _stage_gates(cfg: ArchConfig, gates0: Optional[jax.Array]):
    """Depth gates per stage: FedFA flexes stage 0; later stages stay full."""
    gs = _full_gates(cfg)
    if gates0 is not None:
        gs[0] = gates0
    return gs


def _embed(params: Params, cfg: ArchConfig, tokens: jax.Array,
           m: WidthMasks, offset=0) -> jax.Array:
    x = params["embed"][tokens]
    if cfg.family == "dense" or True:
        pass
    if cfg.rope_theta <= 0.0 and "pos_embed" in params:
        S = tokens.shape[1]
        pos = jax.lax.dynamic_slice_in_dim(params["pos_embed"], offset, S, 0)
        x = x + pos[None]
    if m.d_model is not None:
        x = x * m.d_model.astype(x.dtype)
    return x


def _head(params: Params, cfg: ArchConfig, x: jax.Array, m: WidthMasks):
    x = apply_norm(cfg.norm, x, params["final_norm"], m.d_model, cfg.norm_eps)
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ w.astype(x.dtype)
    logits = softcap(logits, cfg.logit_softcap)
    if cfg.padded_vocab != cfg.vocab_size:
        # mask vocab-padding logits (sharding-only rows)
        pad_mask = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
        logits = jnp.where(pad_mask, logits, -1e30)
    return logits


def _encoder_apply(params: Params, cfg: ArchConfig, frames: jax.Array,
                   m: WidthMasks):
    """Whisper encoder over precomputed frame embeddings (stub frontend)."""
    enc = params["encoder"]
    x = frames
    if "pos_embed" in params:
        S = frames.shape[1]
        x = x + params["pos_embed"][None, :S]
    if m.d_model is not None:
        x = x * m.d_model.astype(x.dtype)
    positions = jnp.arange(frames.shape[1])[None]
    x, _, _ = _stage_apply((enc["blocks"],), ("attn",), x, cfg, m,
                           gates=jnp.ones((cfg.encoder.n_layers,), jnp.float32),
                           positions=positions, window=None,
                           causal=False, remat=cfg.remat)
    return apply_norm(cfg.norm, x, enc["final_norm"], m.d_model, cfg.norm_eps)


def _project_patches(params: Params, patches: jax.Array, m: WidthMasks):
    pr = params["projector"]
    h = jax.nn.gelu(patches @ pr["w1"])
    h = h @ pr["w2"]
    if m.d_model is not None:
        h = h * m.d_model.astype(h.dtype)
    return h


def forward(params: Params, cfg: ArchConfig, batch: Dict[str, jax.Array], *,
            masks: Optional[WidthMasks] = None,
            gates: Optional[jax.Array] = None,
            window: Optional[int] = None,
            remat: Optional[bool] = None) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Training / evaluation forward pass.

    batch: {'tokens': (B, S) [, 'patches': (B, P, vit_dim)]
            [, 'frames': (B, T, D)]}.
    Returns (logits (B, S*, V), aux losses).
    """
    m = masks or full_masks(cfg)
    remat = cfg.remat if remat is None else remat
    tokens = batch["tokens"]
    x = _embed(params, cfg, tokens, m)
    enc_out = None
    if cfg.encoder is not None:
        enc_out = _encoder_apply(params, cfg, batch["frames"], m)
    if cfg.vision is not None:
        pe = _project_patches(params, batch["patches"], m)
        x = jnp.concatenate([pe.astype(x.dtype), x], axis=1)
    positions = jnp.arange(x.shape[1])[None]
    win = window if window is not None else cfg.attn_window
    aux_tot = {"lb_loss": jnp.zeros((), jnp.float32),
               "z_loss": jnp.zeros((), jnp.float32)}
    sg = _stage_gates(cfg, gates)
    for i, (unit, reps) in enumerate(cfg.stages()):
        x, _, aux = _stage_apply(params["stages"][i], unit, x, cfg, m,
                                 gates=sg[i], positions=positions,
                                 window=win, enc_out=enc_out, remat=remat)
        for k in aux_tot:
            aux_tot[k] = aux_tot[k] + aux[k]
    logits = _head(params, cfg, x, m)
    if cfg.vision is not None:
        logits = logits[:, batch["patches"].shape[1]:]   # text positions only
    return logits, aux_tot


def lm_loss(logits: jax.Array, tokens: jax.Array,
            class_mask: Optional[jax.Array] = None) -> jax.Array:
    """Next-token cross entropy. class_mask: (V,) float — non-IID clients
    zero-out logits of absent classes (paper §5.1)."""
    lg = logits[:, :-1].astype(jnp.float32)
    tgt = tokens[:, 1:]
    if class_mask is not None:
        lg = jnp.where(class_mask[None, None] > 0, lg, -1e30)
    lp = jax.nn.log_softmax(lg, axis=-1)
    nll = -jnp.take_along_axis(lp, tgt[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


def cls_loss(logits: jax.Array, labels: jax.Array,
             class_mask: Optional[jax.Array] = None) -> jax.Array:
    """Sequence classification: mean-pool positions -> class logits live in
    the first n_classes vocab slots (paper's image-classification analog)."""
    lg = jnp.mean(logits.astype(jnp.float32), axis=1)
    if class_mask is not None:
        lg = jnp.where(class_mask[None] > 0, lg, -1e30)
    lp = jax.nn.log_softmax(lg, axis=-1)
    return -jnp.mean(jnp.take_along_axis(lp, labels[:, None], axis=-1))


def loss_fn(params: Params, cfg: ArchConfig, batch: Dict[str, jax.Array], *,
            masks=None, gates=None, task: str = "lm",
            class_mask=None) -> Tuple[jax.Array, Dict]:
    logits, aux = forward(params, cfg, batch, masks=masks, gates=gates)
    if task == "lm":
        base = lm_loss(logits, batch["tokens"], class_mask)
    else:
        base = cls_loss(logits, batch["labels"], class_mask)
    total = base + aux["lb_loss"] + aux["z_loss"]
    return total, {"loss": base, **aux}


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------

def init_caches(params: Params, cfg: ArchConfig, batch: int, capacity: int, *,
                window: Optional[int] = None, dtype=jnp.bfloat16):
    """Allocate per-stage stacked caches mirroring params['stages']."""
    win = window if window is not None else cfg.attn_window
    kv_cap = min(capacity, win) if win else capacity
    ring = bool(win) and kv_cap < capacity
    out = []
    for unit, reps in cfg.stages():
        stage = []
        for kind in unit:
            if kind == "attn":
                c = {"self": attn_mod.init_kv_cache(
                    batch, kv_cap, cfg.n_kv_heads, cfg.head_dim, dtype)}
            elif kind == "ssd":
                c = {"ssm": ssm_mod.init_ssm_cache(batch, cfg.d_model, cfg.ssm, dtype)}
            elif kind == "rglru":
                c = {"rg": rglru_mod.init_rglru_cache(batch, cfg.d_model, cfg.rglru, dtype)}
            else:
                raise ValueError(kind)
            stage.append(jax.tree.map(
                lambda x: jnp.broadcast_to(x[None], (reps,) + x.shape), c))
        out.append(tuple(stage))
    return tuple(out)


def prefill(params: Params, cfg: ArchConfig, batch: Dict[str, jax.Array], *,
            masks=None, gates=None, capacity: Optional[int] = None,
            window: Optional[int] = None, cache_dtype=jnp.bfloat16,
            chunk_size: Optional[int] = None):
    """Process the prompt; returns (last-position logits, caches, enc_out).

    ``chunk_size``: chunked prefill — run the prompt in chunks against the
    growing KV cache.  Bounds token-count-proportional buffers (MoE
    dispatch: 75 GB/dev -> a few GB for arctic prefill_32k).  Full-cache
    attention archs only (no ring caches / enc-dec).
    """
    m = masks or full_masks(cfg)
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = _embed(params, cfg, tokens, m)
    enc_out = None
    if cfg.encoder is not None:
        enc_out = _encoder_apply(params, cfg, batch["frames"], m)
    if cfg.vision is not None:
        pe = _project_patches(params, batch["patches"], m)
        x = jnp.concatenate([pe.astype(x.dtype), x], axis=1)
    Sx = x.shape[1]
    caches = init_caches(params, cfg, B, capacity or Sx, window=window,
                         dtype=cache_dtype)
    win = window if window is not None else cfg.attn_window
    sg = _stage_gates(cfg, gates)

    chunk = chunk_size if chunk_size is None else (
        None if (win is not None or cfg.encoder is not None
                 or Sx % chunk_size or Sx <= chunk_size) else chunk_size)
    if chunk is None:
        positions = jnp.arange(Sx)[None]
        new_caches = []
        for i, (unit, reps) in enumerate(cfg.stages()):
            x, nc, _ = _stage_apply(params["stages"][i], unit, x, cfg, m,
                                    gates=sg[i], positions=positions,
                                    window=win, enc_out=enc_out,
                                    caches=caches[i], remat=False)
            new_caches.append(nc)
        logits = _head(params, cfg, x[:, -1:], m)
        return logits, tuple(new_caches), enc_out

    nc_chunks = Sx // chunk
    xc = jnp.moveaxis(x.reshape(B, nc_chunks, chunk, x.shape[-1]), 1, 0)

    def body(caches, inp):
        xch, off = inp
        positions = (off + jnp.arange(chunk))[None]
        new_caches = []
        for i, (unit, reps) in enumerate(cfg.stages()):
            xch, ncs, _ = _stage_apply(params["stages"][i], unit, xch, cfg, m,
                                       gates=sg[i], positions=positions,
                                       window=win, caches=caches[i],
                                       remat=False, chunk_offset=off)
            new_caches.append(ncs)
        return tuple(new_caches), xch[:, -1:]

    offsets = jnp.arange(nc_chunks) * chunk
    caches, lasts = jax.lax.scan(body, caches, (xc, offsets))
    logits = _head(params, cfg, lasts[-1], m)
    return logits, caches, enc_out


def decode_step(params: Params, cfg: ArchConfig, token: jax.Array,
                caches, *, masks=None, gates=None, pos: Optional[jax.Array] = None,
                window: Optional[int] = None, enc_out=None):
    """One autoregressive step. token: (B, 1). Returns (logits, caches)."""
    m = masks or full_masks(cfg)
    if pos is None:
        pos = _cache_pos(caches)
    x = _embed(params, cfg, token, m, offset=0)
    if cfg.rope_theta <= 0.0 and "pos_embed" in params:
        # re-add position at the true offset (embed used offset 0)
        x = x - params["pos_embed"][None, 0:1] + jax.lax.dynamic_slice_in_dim(
            params["pos_embed"], pos, 1, 0)[None]
    positions = jnp.full((token.shape[0], 1), pos, jnp.int32)
    win = window if window is not None else cfg.attn_window
    new_caches = []
    sg = _stage_gates(cfg, gates)
    for i, (unit, reps) in enumerate(cfg.stages()):
        x, nc, _ = _stage_apply(params["stages"][i], unit, x, cfg, m,
                                gates=sg[i], positions=positions,
                                window=win, enc_out=enc_out,
                                caches=caches[i], decode=True, remat=False)
        new_caches.append(nc)
    logits = _head(params, cfg, x, m)
    return logits, tuple(new_caches)


def _cache_pos(caches) -> jax.Array:
    """Current length from the first cache leaf named 'pos'."""
    first_stage = caches[0][0]
    c = next(iter(first_stage.values()))
    return jnp.max(c.pos) if hasattr(c, "pos") else jnp.zeros((), jnp.int32)
