"""Composable decoder stack: dense / GQA / MoE / SSD / RG-LRU blocks,
scan-staged, FedFA width-masked and depth-gated, with serving caches.

Every block is residual (`x + gate_r * f_r(x)`) which is exactly the
property FedFA's layer grafting relies on (paper Appendix B).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import rglru as rglru_mod
from repro.models import ssm as ssm_mod
from repro.models.attention import KVCache
from repro.models.layers import (activation, apply_norm, dense_init,
                                 init_norm, sinusoidal_positions, softcap)
from repro.models.masks import WidthMasks, full_masks
from repro.sharding import hints

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _init_ffn(key, cfg: ArchConfig, dtype) -> Params:
    if cfg.norm == "layernorm":        # whisper-style plain MLP with biases
        k1, k2 = jax.random.split(key)
        return {"w_in": dense_init(k1, (cfg.d_model, cfg.d_ff), dtype),
                "b_in": jnp.zeros((cfg.d_ff,), dtype),
                "w_out": dense_init(k2, (cfg.d_ff, cfg.d_model), dtype),
                "b_out": jnp.zeros((cfg.d_model,), dtype)}
    k1, k2, k3 = jax.random.split(key, 3)
    return {"w_gate": dense_init(k1, (cfg.d_model, cfg.d_ff), dtype),
            "w_up": dense_init(k2, (cfg.d_model, cfg.d_ff), dtype),
            "w_down": dense_init(k3, (cfg.d_ff, cfg.d_model), dtype)}


def _init_attn(key, cfg: ArchConfig, dtype, n_heads=None, n_kv=None) -> Params:
    H = n_heads or cfg.n_heads
    K = n_kv or cfg.n_kv_heads
    hd = cfg.head_dim
    ks = jax.random.split(key, 4)
    return {"wq": dense_init(ks[0], (cfg.d_model, H * hd), dtype),
            "wk": dense_init(ks[1], (cfg.d_model, K * hd), dtype),
            "wv": dense_init(ks[2], (cfg.d_model, K * hd), dtype),
            "wo": dense_init(ks[3], (H * hd, cfg.d_model), dtype)}


def _init_block(key, kind: str, cfg: ArchConfig, dtype, cross: bool = False) -> Params:
    ks = jax.random.split(key, 6)
    if kind == "attn":
        p = {"ln1": init_norm(cfg.norm, cfg.d_model, dtype),
             "attn": _init_attn(ks[0], cfg, dtype),
             "ln2": init_norm(cfg.norm, cfg.d_model, dtype)}
        if cfg.moe:
            p["ffn"] = moe_mod.init_moe(ks[1], cfg.d_model, cfg.moe, dtype)
        else:
            p["ffn"] = _init_ffn(ks[1], cfg, dtype)
        if cross:
            p["lnx"] = init_norm(cfg.norm, cfg.d_model, dtype)
            p["xattn"] = _init_attn(ks[2], cfg, dtype)
        return p
    if kind == "ssd":
        return {"ln": init_norm(cfg.norm, cfg.d_model, dtype),
                "ssd": ssm_mod.init_ssd(ks[0], cfg.d_model, cfg.ssm, dtype)}
    if kind == "rglru":
        return {"ln1": init_norm(cfg.norm, cfg.d_model, dtype),
                "rg": rglru_mod.init_rglru(ks[0], cfg.d_model, cfg.rglru, dtype),
                "ln2": init_norm(cfg.norm, cfg.d_model, dtype),
                "ffn": _init_ffn(ks[1], cfg, dtype)}
    raise ValueError(kind)


def _stack_init(key, n: int, fn):
    return jax.vmap(fn)(jax.random.split(key, n))


def init_params(cfg: ArchConfig, key, dtype=jnp.float32) -> Params:
    keys = jax.random.split(key, 8)
    D, V = cfg.d_model, cfg.padded_vocab
    p: Params = {"embed": dense_init(keys[0], (V, D), dtype, scale=1.0)}
    stages = []
    for i, (unit, reps) in enumerate(cfg.stages()):
        ku = jax.random.split(keys[1], len(unit) * (i + 1) + 7)
        stage = tuple(
            _stack_init(ku[j + i * len(unit)], reps,
                        functools.partial(_init_block, kind=kind, cfg=cfg,
                                          dtype=dtype,
                                          cross=cfg.encoder is not None))
            for j, kind in enumerate(unit))
        stages.append(stage)
    p["stages"] = tuple(stages)
    p["final_norm"] = init_norm(cfg.norm, D, dtype)
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(keys[2], (D, V), dtype)
    if cfg.rope_theta <= 0.0:
        p["pos_embed"] = (0.02 * jax.random.normal(
            keys[3], (max(cfg.max_seq_len, 2048), D))).astype(dtype)
    if cfg.vision is not None:
        k1, k2 = jax.random.split(keys[4])
        p["projector"] = {
            "w1": dense_init(k1, (cfg.vision.vit_dim, D), dtype),
            "w2": dense_init(k2, (D, D), dtype)}
    if cfg.encoder is not None:
        enc_stage = _stack_init(
            keys[5], cfg.encoder.n_layers,
            functools.partial(_init_block, kind="attn", cfg=cfg, dtype=dtype))
        p["encoder"] = {"blocks": enc_stage,
                        "final_norm": init_norm(cfg.norm, D, dtype)}
    return p


# ---------------------------------------------------------------------------
# Block application
# ---------------------------------------------------------------------------

def _ffn_apply(p: Params, x, cfg: ArchConfig, m: WidthMasks):
    if cfg.norm == "layernorm":
        h = activation(cfg.act)(x @ p["w_in"] + p["b_in"])
        if m.d_ff is not None:
            h = h * m.d_ff.astype(h.dtype)
        return h @ p["w_out"] + p["b_out"], {}
    act = activation(cfg.act)
    h = act(x @ p["w_gate"]) * (x @ p["w_up"])
    h = hints.constrain(h, "ffn")
    if m.d_ff is not None:
        h = h * m.d_ff.astype(h.dtype)
    return h @ p["w_down"], {}


def _mix_ffn(p: Params, x, cfg: ArchConfig, m: WidthMasks):
    if cfg.moe:
        return moe_mod.moe_ffn(p, x, cfg.moe, cfg.act,
                               expert_mask=m.experts, d_ff_mask=None)
    return _ffn_apply(p, x, cfg, m)


def _attn_apply(p: Params, x, cfg: ArchConfig, m: WidthMasks, *,
                positions, causal=True, window=None,
                kv_override: Optional[Tuple[jax.Array, jax.Array]] = None,
                cache: Optional[KVCache] = None, decode=False,
                chunk_offset=None):
    """Self or cross attention.  x: (B, S, D). Returns (out, new_cache)."""
    B, S, D = x.shape
    hd = cfg.head_dim
    H, K = cfg.n_heads, cfg.n_kv_heads
    q = (x @ p["wq"]).reshape(B, S, H, hd)
    if kv_override is None:
        k = (x @ p["wk"]).reshape(B, S, K, hd)
        v = (x @ p["wv"]).reshape(B, S, K, hd)
        q = attn_mod.apply_rope(q, positions, cfg.rope_theta)
        k = attn_mod.apply_rope(k, positions, cfg.rope_theta)
    else:
        kv_src = kv_override[0]
        Sk = kv_src.shape[1]
        k = (kv_src @ p["wk"]).reshape(B, Sk, K, hd)
        v = (kv_src @ p["wv"]).reshape(B, Sk, K, hd)
    new_cache = None
    if cache is not None and kv_override is None:
        ring = window is not None and cache.capacity <= window
        cache = attn_mod.cache_extend(cache, k, v, ring=ring)
        new_cache = cache
        if decode:
            out = attn_mod.attend_decode(q, cache, ring=ring, window=window,
                                         head_mask=m.heads)
        elif chunk_offset is not None:
            # chunked prefill: attend this chunk's queries against the
            # whole cache so far (causal mask via q_offset; unwritten
            # slots are beyond every qpos and masked out).
            out = attn_mod.attend(q, cache.k, cache.v, causal=True,
                                  window=window, head_mask=m.heads,
                                  q_offset=chunk_offset)
        else:
            out = attn_mod.attend(q, k, v, causal=causal, window=window,
                                  head_mask=m.heads)
    else:
        out = attn_mod.attend(q, k, v, causal=causal, window=window,
                              head_mask=m.heads,
                              q_offset=0)
    out = hints.constrain(out, "heads")
    y = out.reshape(B, S, H * hd) @ p["wo"]
    return y, new_cache


def _block_apply(kind: str, p: Params, x, cfg: ArchConfig, m: WidthMasks, *,
                 gate, positions, window, enc_out=None, cache=None,
                 decode=False, causal=True, chunk_offset=None):
    """One residual block. Returns (x, new_cache, aux)."""
    aux = {}
    new_cache = cache
    dm = m.d_model
    if kind == "attn":
        h = apply_norm(cfg.norm, x, p["ln1"], dm, cfg.norm_eps)
        a, c_new = _attn_apply(p["attn"], h, cfg, m, positions=positions,
                               causal=causal, window=window,
                               cache=None if cache is None else cache["self"],
                               decode=decode, chunk_offset=chunk_offset)
        x = x + (gate * a.astype(jnp.float32)).astype(x.dtype)
        if enc_out is not None and "xattn" in p:
            h = apply_norm(cfg.norm, x, p["lnx"], dm, cfg.norm_eps)
            a, _ = _attn_apply(p["xattn"], h, cfg, m, positions=positions,
                               causal=False, kv_override=(enc_out, enc_out))
            x = x + (gate * a.astype(jnp.float32)).astype(x.dtype)
        h = apply_norm(cfg.norm, x, p["ln2"], dm, cfg.norm_eps)
        f, fa = _mix_ffn(p["ffn"], h, cfg, m)
        aux.update(fa)
        x = x + (gate * f.astype(jnp.float32)).astype(x.dtype)
        x = hints.constrain(x, "residual")
        if cache is not None:
            new_cache = dict(cache, self=c_new)
        return x, new_cache, aux
    if kind == "ssd":
        h = apply_norm(cfg.norm, x, p["ln"], dm, cfg.norm_eps)
        if decode:
            f, c_new = ssm_mod.ssd_decode(p["ssd"], h, cfg.ssm, cfg.d_model,
                                          cache["ssm"], head_mask=m.ssm_heads,
                                          d_model_mask=dm, norm_eps=cfg.norm_eps)
        else:
            f, c_new = ssm_mod.ssd_forward(p["ssd"], h, cfg.ssm, cfg.d_model,
                                           head_mask=m.ssm_heads,
                                           d_model_mask=dm, norm_eps=cfg.norm_eps,
                                           cache=None if cache is None else cache["ssm"])
        x = x + (gate * f.astype(jnp.float32)).astype(x.dtype)
        if cache is not None:
            new_cache = dict(cache, ssm=c_new)
        return x, new_cache, aux
    if kind == "rglru":
        h = apply_norm(cfg.norm, x, p["ln1"], dm, cfg.norm_eps)
        if decode:
            f, c_new = rglru_mod.rglru_decode(p["rg"], h, cfg.rglru, cfg.d_model,
                                              cache["rg"], mask_dr=m.d_rnn,
                                              d_model_mask=dm)
        else:
            f, c_new = rglru_mod.rglru_block(p["rg"], h, cfg.rglru, cfg.d_model,
                                             mask_dr=m.d_rnn, d_model_mask=dm,
                                             cache=None if cache is None else cache["rg"])
        x = x + (gate * f.astype(jnp.float32)).astype(x.dtype)
        h = apply_norm(cfg.norm, x, p["ln2"], dm, cfg.norm_eps)
        f, fa = _ffn_apply(p["ffn"], h, cfg, m)
        x = x + (gate * f.astype(jnp.float32)).astype(x.dtype)
        if cache is not None:
            new_cache = dict(cache, rg=c_new)
        return x, new_cache, aux
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Stage scan
# ---------------------------------------------------------------------------

def _stage_apply(stage_params, unit: Tuple[str, ...], x, cfg: ArchConfig,
                 m: WidthMasks, *, gates, positions, window, enc_out=None,
                 caches=None, decode=False, causal=True, remat=False,
                 chunk_offset=None):
    """Scan over the repeat axis of one stage."""
    has_cache = caches is not None

    def run_unit(x, p_r, gate_r, cache_r):
        new_caches = []
        lb = jnp.zeros((), jnp.float32)
        zl = jnp.zeros((), jnp.float32)
        for j, kind in enumerate(unit):
            x, nc, aux = _block_apply(
                kind, p_r[j], x, cfg, m, gate=gate_r, positions=positions,
                window=window, enc_out=enc_out, cache=cache_r[j],
                decode=decode, causal=causal, chunk_offset=chunk_offset)
            new_caches.append(nc)
            lb = lb + aux.get("lb_loss", 0.0)
            zl = zl + aux.get("z_loss", 0.0)
        return x, tuple(new_caches), lb, zl

    if has_cache:
        # Cache lives in the scan CARRY and is updated in place per repeat
        # (dynamic_update_index); carrying it — instead of xs->ys streaming —
        # lets XLA alias the buffers instead of double-buffering the whole
        # stacked cache (§Perf iter 1: -7 GB on minicpm decode_32k).
        def body(carry, xs):
            x, call, r = carry
            p_r, gate_r = xs
            cache_r = jax.tree.map(
                lambda c: jax.lax.dynamic_index_in_dim(c, r, 0, keepdims=False),
                call)
            x, ncs, lb, zl = run_unit(x, p_r, gate_r, cache_r)
            call = jax.tree.map(
                lambda c, n: jax.lax.dynamic_update_index_in_dim(
                    c, n.astype(c.dtype), r, 0),
                call, ncs)
            return (x, call, r + 1), (lb, zl)

        (x, new_caches, _), (lb, zl) = jax.lax.scan(
            body, (x, caches, jnp.zeros((), jnp.int32)), (stage_params, gates))
    else:
        def body(x, xs):
            p_r, gate_r = xs
            x, _, lb, zl = run_unit(x, p_r, gate_r, (None,) * len(unit))
            return x, (lb, zl)

        if remat:
            body = jax.checkpoint(body)
        x, (lb, zl) = jax.lax.scan(body, x, (stage_params, gates))
        new_caches = None
    return x, new_caches, {"lb_loss": jnp.sum(lb), "z_loss": jnp.sum(zl)}
