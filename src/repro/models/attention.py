"""Grouped-query attention with KV caches, sliding windows, and head masks.

Three entry points used by the transformer stack:
  * ``attend``            — full-sequence attention (train / prefill)
  * ``attend_decode``     — one-token step against a (possibly ring) KV cache
  * ``init_kv_cache``     — allocate the cache for serving

Ring-ness of a cache is a *static* property derived from shapes
(capacity <= window), so the cache pytree carries only arrays.

The dense math path is XLA (this is what multi-pod dry-runs lower); the
Pallas flash-attention kernel in ``repro.kernels.flash_attention`` is the
TPU hot path and is validated against :func:`attend` in tests.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.layers import apply_rope  # re-export for transformer.py

NEG_INF = -2.0 ** 30


class KVCache(NamedTuple):
    k: jax.Array          # (B, C, K, hd) — C = seq capacity or ring window
    v: jax.Array
    pos: jax.Array        # () int32: number of tokens already written

    @property
    def capacity(self) -> int:
        return self.k.shape[1]


def init_kv_cache(batch: int, capacity: int, n_kv: int, head_dim: int,
                  dtype) -> KVCache:
    z = jnp.zeros((batch, capacity, n_kv, head_dim), dtype)
    return KVCache(z, z, jnp.zeros((), jnp.int32))


def _expand_kv(k: jax.Array, n_heads: int) -> jax.Array:
    """(B, S, K, hd) -> (B, S, H, hd) by repeating each kv head H/K times."""
    n_kv = k.shape[2]
    rep = n_heads // n_kv
    if rep == 1:
        return k
    return jnp.repeat(k, rep, axis=2)


# blocked path kicks in above this q*k footprint (elements per head-batch)
_BLOCKED_THRESHOLD = 2048 * 2048
# roofline probes set this: python-unrolled block loops so XLA's cost
# analysis (which counts while bodies once) sees every block.
_FORCE_UNROLL = False


def attend(q: jax.Array, k: jax.Array, v: jax.Array, *,
           causal: bool = True,
           window: Optional[int] = None,
           head_mask: Optional[jax.Array] = None,
           q_offset: int = 0) -> jax.Array:
    """Attention entry point.  q: (B, Sq, H, hd); k,v: (B, Sk, K, hd).

    ``head_mask``: (H,) float — FedFA width mask; masked heads output zeros.
    ``window``: sliding-window causal attention (attend to <= window-1 back).

    Long sequences route to :func:`attend_blocked` (online-softmax over kv
    chunks, flash-attention memory behaviour in pure XLA) so prefill_32k /
    train_4k never materialize S² logits; the Pallas kernel replaces this
    on real TPUs.
    """
    Sq, Sk = q.shape[1], k.shape[1]
    if Sq * Sk > _BLOCKED_THRESHOLD and Sq > 1:
        if _FORCE_UNROLL:
            return attend_blocked(q, k, v, causal=causal, window=window,
                                  head_mask=head_mask, q_offset=q_offset,
                                  bq=2048, bk=2048, unroll=True)
        return attend_blocked(q, k, v, causal=causal, window=window,
                              head_mask=head_mask, q_offset=q_offset)
    return _attend_dense(q, k, v, causal=causal, window=window,
                         head_mask=head_mask, q_offset=q_offset)


def _attend_dense(q, k, v, *, causal=True, window=None, head_mask=None,
                  q_offset=0) -> jax.Array:
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    k = _expand_kv(k, H)
    v = _expand_kv(v, H)
    scale = hd ** -0.5
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    qpos = jnp.arange(Sq) + q_offset
    kpos = jnp.arange(Sk)
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window is not None:
        mask &= kpos[None, :] > qpos[:, None] - window
    logits = jnp.where(mask[None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v)
    if head_mask is not None:
        out = out * head_mask[None, None, :, None].astype(out.dtype)
    return out


def attend_blocked(q: jax.Array, k: jax.Array, v: jax.Array, *,
                   causal: bool = True, window: Optional[int] = None,
                   head_mask: Optional[jax.Array] = None, q_offset: int = 0,
                   bq: int = 512, bk: int = 1024,
                   unroll: bool = False) -> jax.Array:
    """Online-softmax blocked attention (flash semantics in pure XLA).

    Peak live memory per device is O(bq·bk) logits instead of O(Sq·Sk).
    Exact (not approximate); validated against `_attend_dense` in tests.
    """
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    pad_q = (-Sq) % bq
    pad_k = (-Sk) % bk
    qf = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0))) if pad_q else q
    kf = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0))) if pad_k else k
    vf = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0))) if pad_k else v
    nq, nk = (Sq + pad_q) // bq, (Sk + pad_k) // bk
    scale = hd ** -0.5
    Kh = kf.shape[2]
    qb = qf.reshape(B, nq, bq, H, hd)
    kb = kf.reshape(B, nk, bk, Kh, hd)
    vb = vf.reshape(B, nk, bk, Kh, hd)

    def q_block(i, qi):
        # qi: (B, bq, H, hd)
        @jax.checkpoint
        def kv_step(carry, j):
            m_run, l_run, acc = carry
            kj = jax.lax.dynamic_index_in_dim(kb, j, 1, keepdims=False)
            vj = jax.lax.dynamic_index_in_dim(vb, j, 1, keepdims=False)
            kj = _expand_kv(kj, H)
            vj = _expand_kv(vj, H)
            # bf16 inputs: keep operands bf16, accumulate f32 on the MXU —
            # halves the dominant HBM traffic of the blocked attention
            # (§Perf iter 3); f32 inputs keep the exact path for tests.
            fast = qi.dtype == jnp.bfloat16
            if fast:
                s = jnp.einsum("bqhd,bkhd->bhqk", qi, kj,
                               preferred_element_type=jnp.float32) * scale
            else:
                s = jnp.einsum("bqhd,bkhd->bhqk",
                               qi.astype(jnp.float32) * scale,
                               kj.astype(jnp.float32))
            qpos = i * bq + jnp.arange(bq)[:, None] + q_offset
            kpos = j * bk + jnp.arange(bk)[None, :]
            mask = kpos < Sk
            if causal:
                mask &= kpos <= qpos
            if window is not None:
                mask &= kpos > qpos - window
            s = jnp.where(mask[None, None], s, NEG_INF)
            m_cur = jnp.max(s, axis=-1, keepdims=True)
            m_new = jnp.maximum(m_run, m_cur)
            p = jnp.where(mask[None, None], jnp.exp(s - m_new), 0.0)
            corr = jnp.exp(m_run - m_new)
            l_new = l_run * corr + jnp.sum(p, axis=-1, keepdims=True)
            pv = jnp.einsum("bhqk,bkhd->bqhd",
                            p.astype(vj.dtype) if fast else p,
                            vj if fast else vj.astype(jnp.float32),
                            preferred_element_type=jnp.float32)
            acc_new = acc * jnp.moveaxis(corr, 1, 2) + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, H, bq, 1), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, H, bq, 1), jnp.float32)
        a0 = jnp.zeros((B, bq, H, hd), jnp.float32)
        # Sliding-window skip (§Perf iter 3b): a q block only overlaps
        # ceil((bq+window)/bk)+1 kv blocks, so iterate that static count
        # from a dynamic start instead of all nk blocks — cuts windowed
        # prefill attention compute/traffic by ~Sk/(bq+window).
        if window is not None and causal:
            nke = min(nk, (bq + window) // bk + 2)
            start = jnp.clip((i * bq + q_offset - window) // bk, 0, nk - nke)
            steps = start + jnp.arange(nke)
        else:
            steps = jnp.arange(nk)
        if unroll:
            carry = (m0, l0, a0)
            for j in range(steps.shape[0]):
                carry, _ = kv_step(carry, steps[j])
            m_f, l_f, acc = carry
        else:
            (m_f, l_f, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), steps)
        l_f = jnp.maximum(l_f, 1e-30)
        return acc / jnp.moveaxis(l_f, 1, 2)

    if unroll:
        out = jnp.stack([q_block(jnp.asarray(i), qb[:, i])
                         for i in range(nq)], axis=0)
    else:
        out = jax.lax.map(lambda args: q_block(*args),
                          (jnp.arange(nq), jnp.moveaxis(qb, 1, 0)))
    out = jnp.moveaxis(out, 0, 1).reshape(B, nq * bq, H, hd)[:, :Sq]
    out = out.astype(q.dtype)
    if head_mask is not None:
        out = out * head_mask[None, None, :, None].astype(out.dtype)
    return out


def cache_extend(cache: KVCache, k_new: jax.Array, v_new: jax.Array,
                 ring: bool = False) -> KVCache:
    """Write S new kv entries (prefill S tokens or decode S=1)."""
    B, S = k_new.shape[:2]
    cap = cache.capacity
    if ring:
        if S >= cap:                   # prefill longer than the window: keep tail
            k_new, v_new = k_new[:, -cap:], v_new[:, -cap:]
            idx = (cache.pos + S - cap + jnp.arange(cap)) % cap
        else:
            idx = (cache.pos + jnp.arange(S)) % cap
    else:
        idx = cache.pos + jnp.arange(S)
    k = cache.k.at[:, idx].set(k_new.astype(cache.k.dtype))
    v = cache.v.at[:, idx].set(v_new.astype(cache.v.dtype))
    return KVCache(k, v, cache.pos + S)


def attend_decode(q: jax.Array, cache: KVCache, *,
                  ring: bool = False,
                  window: Optional[int] = None,
                  head_mask: Optional[jax.Array] = None) -> jax.Array:
    """One-token decode: q (B, 1, H, hd) against the cache (already extended).

    For ring caches the stored order is rotated; attention is permutation-
    invariant given the right validity mask, so we only mask, never unrotate.
    """
    B, _, H, hd = q.shape
    cap = cache.capacity
    k = _expand_kv(cache.k, H)
    v = _expand_kv(cache.v, H)
    scale = hd ** -0.5
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    pos = cache.pos                     # tokens written, incl. current
    slot = jnp.arange(cap)
    if ring:
        written = slot < jnp.minimum(pos, cap)
        if window is not None and window < cap:
            # absolute position of the latest write to slot s
            last_abs = ((pos - 1 - slot) // cap) * cap + slot
            written &= last_abs > pos - 1 - window
        valid = written
    else:
        valid = slot < pos
        if window is not None:
            valid &= slot > pos - 1 - window
    logits = jnp.where(valid[None, None, None, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v)
    if head_mask is not None:
        out = out * head_mask[None, None, :, None].astype(out.dtype)
    return out
