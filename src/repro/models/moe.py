"""Mixture-of-experts FFN with sort-based capacity dispatch.

TPU-native design: instead of (tokens, experts, capacity) one-hot dispatch
tensors (which are infeasible at 1M tokens x 128 experts), token->expert
assignments are sorted by expert id and scattered into fixed (E, C, D)
buffers.  Under pjit with experts sharded over the `model` mesh axis, the
gather/scatter lowers to all-to-all style collectives — the expert-parallel
pattern.

FedFA width flexibility on MoE extends to the *expert axis*: weak clients
hold a contiguous prefix of experts (`expert_mask`), and `d_ff_expert` can
additionally be masked like a dense FFN.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.models.layers import activation, dense_init
from repro.sharding import hints


def init_moe(key, d_model: int, cfg: MoEConfig, dtype) -> dict:
    ks = jax.random.split(key, 5)
    E, Fe = cfg.n_experts, cfg.d_ff_expert
    p = {
        "router": dense_init(ks[0], (d_model, E), jnp.float32),
        "w_gate": dense_init(ks[1], (E, d_model, Fe), dtype),
        "w_up": dense_init(ks[2], (E, d_model, Fe), dtype),
        "w_down": dense_init(ks[3], (E, Fe, d_model), dtype),
    }
    if cfg.dense_residual:
        kd = jax.random.split(ks[4], 3)
        p["dense"] = {
            "w_gate": dense_init(kd[0], (d_model, Fe), dtype),
            "w_up": dense_init(kd[1], (d_model, Fe), dtype),
            "w_down": dense_init(kd[2], (Fe, d_model), dtype),
        }
    return p


def moe_ffn(params: dict, x: jax.Array, cfg: MoEConfig, act_name: str,
            expert_mask: Optional[jax.Array] = None,
            d_ff_mask: Optional[jax.Array] = None,
            capacity: Optional[int] = None):
    """x: (B, S, D) -> (out (B,S,D), aux_losses dict).

    Sort-based dispatch with static capacity C per expert; overflowing
    tokens are dropped (contribute their residual only), standard for
    capacity-based MoE.
    """
    B, S, D = x.shape
    E, k = cfg.n_experts, cfg.top_k
    N = B * S
    act = activation(act_name)
    xf = x.reshape(N, D)

    logits = (xf.astype(jnp.float32) @ params["router"])            # (N, E)
    if expert_mask is not None:
        logits = jnp.where(expert_mask[None, :] > 0, logits, -1e30)
    gates = jax.nn.softmax(logits, axis=-1)
    top_g, top_e = jax.lax.top_k(gates, k)                          # (N, k)
    top_g = top_g / jnp.maximum(top_g.sum(-1, keepdims=True), 1e-9)

    # --- aux losses (Switch-style load balance + router z-loss) ---
    me = jnp.mean(gates, axis=0)                                    # (N,E)->(E,)
    ce = jnp.mean(jax.nn.one_hot(top_e[:, 0], E), axis=0)
    n_active = E if expert_mask is None else jnp.maximum(expert_mask.sum(), 1.0)
    lb_loss = n_active * jnp.sum(me * ce) * cfg.load_balance_loss
    z_loss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2) * cfg.router_z_loss

    # --- sort-based dispatch ---
    C = capacity or max(1, int(cfg.capacity_factor * k * N / E))
    flat_e = top_e.reshape(-1)                                      # (N*k,)
    flat_g = top_g.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(N), k)
    order = jnp.argsort(flat_e)                                     # stable
    se, sg, stok = flat_e[order], flat_g[order], flat_tok[order]
    # segment-relative rank: index within the sorted array minus the start
    # index of this expert's segment.
    seg_start = jnp.searchsorted(se, jnp.arange(E))                 # (E,)
    pos_in_e = jnp.arange(N * k) - seg_start[se]
    keep = pos_in_e < C
    slot = se * C + jnp.where(keep, pos_in_e, 0)                    # (N*k,)

    # gather tokens into (E*C, D)
    # NOTE(§Perf iter 2, refuted hypothesis): forcing P('model',None,None)
    # on the dispatch buffer here materializes replicated->sharded resharding
    # and TRIPLED the measured collective bytes (7.3GB -> 26.3GB full-step);
    # GSPMD's own propagation through the sort-dispatch is better. Left
    # unconstrained deliberately.
    buf = jnp.zeros((E * C, D), x.dtype)
    buf = buf.at[slot].add(jnp.where(keep[:, None], xf[stok], 0))
    buf = buf.reshape(E, C, D)

    # expert computation (E, C, D) x (E, D, Fe)
    wg, wu, wd = params["w_gate"], params["w_up"], params["w_down"]
    if d_ff_mask is not None:
        m = d_ff_mask.astype(wg.dtype)
        wg = wg * m[None, None, :]
        wu = wu * m[None, None, :]
        wd = wd * m[None, :, None]
    h = act(jnp.einsum("ecd,edf->ecf", buf, wg)) * jnp.einsum("ecd,edf->ecf", buf, wu)
    y = jnp.einsum("ecf,efd->ecd", h, wd).reshape(E * C, D)

    # combine back: weighted scatter-add to tokens
    contrib = jnp.where(keep[:, None], y[slot] * sg[:, None].astype(y.dtype), 0)
    out = jnp.zeros((N, D), x.dtype).at[stok].add(contrib)

    if cfg.dense_residual and "dense" in params:
        d = params["dense"]
        out = out + (act(xf @ d["w_gate"]) * (xf @ d["w_up"])) @ d["w_down"]

    return out.reshape(B, S, D), {"lb_loss": lb_loss, "z_loss": z_loss}
