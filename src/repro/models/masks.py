"""FedFA client-architecture runtime: width masks + depth gates + graft maps.

A *client architecture* is (width multiplier, per-section depth).  In the
padded-dense SPMD representation every client shares the global parameter
shapes; this module builds
  * contiguous prefix width masks per flexible dimension (HeteroFL-style
    structured contiguous pruning, paper Alg. 1 line 19),
  * per-repeat depth gates (Alg. 3: clients keep the *first* d_s blocks of
    each section),
  * graft index maps (Alg. 2: missing depth positions are filled with the
    section's last active block).
Everything is a plain jax array so client runtimes can be stacked and
vmapped over the mesh's `data` axis.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig


@dataclass(frozen=True)
class WidthSpec:
    """Integer active sizes per flexible dimension (host-side)."""
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    n_experts: int = 0
    ssm_heads: int = 0
    d_rnn: int = 0


def width_spec(cfg: ArchConfig, w: float) -> WidthSpec:
    """Contiguous-prefix active sizes for width multiplier w in (0, 1]."""
    if not 0.0 < w <= 1.0:
        raise ValueError(f"width multiplier must be in (0, 1], got {w!r}")
    if cfg.n_kv_heads > 0:
        kv = max(1, int(round(w * cfg.n_kv_heads)))
        group = cfg.n_heads // cfg.n_kv_heads
        heads = kv * group
    else:
        kv = heads = 0
    d_model = max(16, int(w * cfg.d_model) // 8 * 8) if w < 1.0 else cfg.d_model
    d_ff = max(8, int(w * cfg.d_ff) // 8 * 8) if (cfg.d_ff and w < 1.0) else cfg.d_ff
    n_exp = 0
    if cfg.moe:
        n_exp = max(cfg.moe.top_k, int(round(w * cfg.moe.n_experts)))
    sh = dr = 0
    if cfg.ssm:
        sh = max(1, int(round(w * cfg.ssm.n_heads(cfg.d_model))))
    if cfg.rglru:
        dr = max(8, int(w * cfg.rglru.d_rnn(cfg.d_model)) // 8 * 8) if w < 1.0 \
            else cfg.rglru.d_rnn(cfg.d_model)
    return WidthSpec(d_model, heads, kv, d_ff, n_exp, sh, dr)


def _prefix(n_total: int, n_active: int) -> jnp.ndarray:
    return (jnp.arange(n_total) < n_active).astype(jnp.float32)


@dataclass(frozen=True)
class WidthMasks:
    d_model: jnp.ndarray                      # (D,)
    heads: Optional[jnp.ndarray]              # (H,)
    kv_heads: Optional[jnp.ndarray]           # (K,)
    d_ff: Optional[jnp.ndarray]               # (F,)
    experts: Optional[jnp.ndarray] = None     # (E,)
    ssm_heads: Optional[jnp.ndarray] = None   # (nh,)
    d_rnn: Optional[jnp.ndarray] = None       # (dr,)


# Registered as a pytree so stacked per-client masks can flow through
# vmap / lax.scan in the aggregation and the federated round step.
jax.tree_util.register_dataclass(
    WidthMasks,
    data_fields=["d_model", "heads", "kv_heads", "d_ff", "experts",
                 "ssm_heads", "d_rnn"],
    meta_fields=[])


def stack_masks(ms: "list[WidthMasks]") -> WidthMasks:
    """Stack per-client masks along a leading client axis."""
    import jax as _jax
    return _jax.tree.map(lambda *xs: jnp.stack(xs), *ms)


def width_masks(cfg: ArchConfig, w: float) -> WidthMasks:
    s = width_spec(cfg, w)
    return WidthMasks(
        d_model=_prefix(cfg.d_model, s.d_model),
        heads=_prefix(cfg.n_heads, s.n_heads) if cfg.n_heads else None,
        kv_heads=_prefix(cfg.n_kv_heads, s.n_kv_heads) if cfg.n_kv_heads else None,
        d_ff=_prefix(cfg.d_ff, s.d_ff) if cfg.d_ff else None,
        experts=_prefix(cfg.moe.n_experts, s.n_experts) if cfg.moe else None,
        ssm_heads=_prefix(cfg.ssm.n_heads(cfg.d_model), s.ssm_heads) if cfg.ssm else None,
        d_rnn=_prefix(cfg.rglru.d_rnn(cfg.d_model), s.d_rnn) if cfg.rglru else None,
    )


def full_masks(cfg: ArchConfig) -> WidthMasks:
    return width_masks(cfg, 1.0)


# ---------------------------------------------------------------------------
# Depth: gates + graft maps over the repeat axis of stage 0
# ---------------------------------------------------------------------------

def max_section_depths(cfg: ArchConfig) -> Tuple[int, ...]:
    return tuple(hi - lo for lo, hi in cfg.section_bounds())


def depth_gates(cfg: ArchConfig, section_depths: Tuple[int, ...]) -> jnp.ndarray:
    """(R,) float gate over stage-0 repeats: first d_s repeats of section s."""
    bounds = cfg.section_bounds()
    if len(section_depths) != len(bounds):
        raise ValueError(
            f"expected {len(bounds)} section depths (one per section), "
            f"got {len(section_depths)}: {section_depths!r}")
    g = np.zeros(cfg.stages()[0][1], np.float32)
    for (lo, hi), d in zip(bounds, section_depths):
        if not 1 <= d <= hi - lo:
            raise ValueError(
                f"depth {d} invalid for section {(lo, hi)}: must be in "
                f"[1, {hi - lo}]")
        g[lo:lo + d] = 1.0
    return jnp.asarray(g)


def graft_map(cfg: ArchConfig, section_depths: Tuple[int, ...]) -> jnp.ndarray:
    """(R,) int32: Alg. 2 — missing repeats replicate the last active block."""
    bounds = cfg.section_bounds()
    m = np.arange(cfg.stages()[0][1], dtype=np.int32)
    for (lo, hi), d in zip(bounds, section_depths):
        m[lo + d:hi] = lo + d - 1
    return jnp.asarray(m)


@dataclass(frozen=True)
class ClientArch:
    """A client's selected architecture (paper Alg. 1 line 2)."""
    width_mult: float
    section_depths: Tuple[int, ...]

    def masks(self, cfg: ArchConfig) -> WidthMasks:
        return width_masks(cfg, self.width_mult)

    def gates(self, cfg: ArchConfig) -> jnp.ndarray:
        return depth_gates(cfg, self.section_depths)

    def graft(self, cfg: ArchConfig) -> jnp.ndarray:
        return graft_map(cfg, self.section_depths)


def full_client(cfg: ArchConfig) -> ClientArch:
    return ClientArch(1.0, max_section_depths(cfg))
