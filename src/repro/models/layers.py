"""Primitive layers: width-aware norms, rotary embeddings, inits.

Width-awareness is the FedFA-critical property: a client whose width mask
zeroes a suffix of channels must compute *exactly* what the corresponding
small dense model computes.  Norms therefore divide by the number of
*active* channels, not the padded dimension.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def rms_norm(x: jax.Array, scale: jax.Array, mask: Optional[jax.Array],
             eps: float = 1e-5) -> jax.Array:
    """RMSNorm over the last dim, counting only active channels."""
    if mask is not None:
        x = x * mask
        n = jnp.maximum(jnp.sum(mask), 1.0)
    else:
        n = x.shape[-1]
    var = jnp.sum(x.astype(jnp.float32) ** 2, axis=-1, keepdims=True) / n
    y = x * jax.lax.rsqrt(var + eps).astype(x.dtype)
    y = y * (1.0 + scale.astype(x.dtype))
    return y * mask if mask is not None else y


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array,
               mask: Optional[jax.Array], eps: float = 1e-5) -> jax.Array:
    """LayerNorm over the last dim, counting only active channels."""
    xf = x.astype(jnp.float32)
    if mask is not None:
        xf = xf * mask
        n = jnp.maximum(jnp.sum(mask), 1.0)
    else:
        n = x.shape[-1]
    mean = jnp.sum(xf, axis=-1, keepdims=True) / n
    if mask is not None:
        cent = (xf - mean) * mask
    else:
        cent = xf - mean
    var = jnp.sum(cent ** 2, axis=-1, keepdims=True) / n
    y = (cent * jax.lax.rsqrt(var + eps)).astype(x.dtype)
    y = y * scale.astype(x.dtype) + bias.astype(x.dtype)
    return y * mask if mask is not None else y


def apply_norm(kind: str, x, p, mask, eps):
    if kind == "rmsnorm":
        return rms_norm(x, p["scale"], mask, eps)
    return layer_norm(x, p["scale"], p["bias"], mask, eps)


def init_norm(kind: str, d: int, dtype) -> dict:
    if kind == "rmsnorm":
        return {"scale": jnp.zeros((d,), dtype)}
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    if theta <= 0.0:
        return x
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)                       # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    angles = angles[..., None, :]                              # (..., S, 1, hd/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(n: int, d: int) -> jax.Array:
    pos = jnp.arange(n, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    angle = pos / jnp.power(10_000.0, 2 * dim / d)
    return jnp.concatenate([jnp.sin(angle), jnp.cos(angle)], axis=-1)


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------

def dense_init(key, shape, dtype, scale: float = 1.0) -> jax.Array:
    """Variance-scaling (fan-in) initializer."""
    fan_in = shape[0] if len(shape) == 2 else shape[-2]
    std = scale / math.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def activation(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[name]


def softcap(x: jax.Array, cap: Optional[float]) -> jax.Array:
    if cap is None:
        return x
    return (cap * jnp.tanh(x.astype(jnp.float32) / cap)).astype(x.dtype)
