"""Batching: build per-round stacked client batches for the SPMD FL round."""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.data import synthetic


def round_batches_cls(parts: Sequence[dict], selected: Sequence[int],
                      n_classes: int, vocab: int, *, local_steps: int,
                      batch: int, seq_len: int, profiles: np.ndarray,
                      seed: int = 0) -> Dict[str, np.ndarray]:
    """Classification task: {'tokens': (m,E,B,S), 'labels': (m,E,B)} drawn
    from each selected client's class distribution."""
    rng = np.random.default_rng(seed)
    toks, labs = [], []
    for ci in selected:
        classes = parts[ci]["classes"]
        n = local_steps * batch
        labels = rng.choice(classes, size=n).astype(np.int32)
        d = synthetic.classification(n_classes, vocab, n, seq_len,
                                     profiles=profiles, labels=labels,
                                     seed=int(rng.integers(2**31)))
        toks.append(d["tokens"].reshape(local_steps, batch, seq_len))
        labs.append(d["labels"].reshape(local_steps, batch))
    return {"tokens": np.stack(toks), "labels": np.stack(labs)}


def round_batches_lm(selected: Sequence[int], vocab: int, *, local_steps: int,
                     batch: int, seq_len: int, domain_T, client_domains,
                     seed: int = 0) -> Dict[str, np.ndarray]:
    """LM task: each client samples from its own domain (non-IID text)."""
    rng = np.random.default_rng(seed)
    toks = []
    for ci in selected:
        T = [domain_T[client_domains[ci]]]
        d = synthetic.lm_stream(vocab, local_steps * batch, seq_len,
                                domain_T=T, seed=int(rng.integers(2**31)))
        toks.append(d.reshape(local_steps, batch, seq_len))
    return {"tokens": np.stack(toks)}


def eval_batch_cls(n_classes: int, vocab: int, n: int, seq_len: int,
                   profiles: np.ndarray, *, classes=None, seed: int = 1):
    rng = np.random.default_rng(seed)
    pool = np.arange(n_classes) if classes is None else np.asarray(classes)
    labels = rng.choice(pool, size=n).astype(np.int32)
    return synthetic.classification(n_classes, vocab, n, seq_len,
                                    profiles=profiles, labels=labels,
                                    seed=seed + 1)
