"""Federated data partitioning (paper §5.1).

IID: every client sees all classes; sample counts vary uniformly such that
the minimum can be up to half the maximum.
Non-IID: each client holds 20% of the classes with equal samples per class;
during local training absent-class logits are zeroed (class masks).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import numpy as np


def iid_partition(n_clients: int, n_classes: int, *,
                  n_data_range: Tuple[int, int] = (100, 250), seed: int = 0):
    """Returns per-client (classes, n_data, class_mask=None)."""
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n_clients):
        out.append(dict(classes=np.arange(n_classes),
                        n_data=int(rng.integers(*n_data_range)),
                        class_mask=None))
    return out


def noniid_partition(n_clients: int, n_classes: int, *,
                     class_frac: float = 0.2,
                     n_data_range: Tuple[int, int] = (100, 250),
                     seed: int = 0):
    """Each client gets ``class_frac`` of the classes + a logit mask."""
    rng = np.random.default_rng(seed)
    k = max(1, int(round(class_frac * n_classes)))
    out = []
    for _ in range(n_clients):
        classes = rng.choice(n_classes, size=k, replace=False)
        mask = np.zeros(n_classes, np.float32)
        mask[classes] = 1.0
        out.append(dict(classes=np.sort(classes),
                        n_data=int(rng.integers(*n_data_range)),
                        class_mask=mask))
    return out


def client_class_mask(part: dict, vocab: int) -> Optional[np.ndarray]:
    """Extend an n_classes mask to the model's vocab-sized logit mask."""
    if part["class_mask"] is None:
        return None
    m = np.zeros(vocab, np.float32)
    m[: len(part["class_mask"])] = part["class_mask"]
    return m
