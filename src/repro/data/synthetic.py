"""Synthetic datasets (offline container; distributions mirror the paper's).

* ``lm_stream``      — mixture-of-bigram language data with Zipf unigram
                       marginals; per-domain bigram structure gives models
                       something real to learn (perplexity drops with
                       training), standing in for WikiText-2 (Table 3).
* ``classification`` — class-conditional token sequences standing in for
                       CIFAR-10/100 / Fashion-MNIST: class c draws tokens
                       from softmax(z_c) so a mean-pool classifier can
                       separate classes (Table 1 analog).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import numpy as np


def _zipf_probs(vocab: int, a: float = 1.2) -> np.ndarray:
    p = 1.0 / np.arange(1, vocab + 1) ** a
    return p / p.sum()


def make_bigram_lm(vocab: int, n_domains: int = 4, seed: int = 0):
    """Returns (sample_fn, domain transition matrices)."""
    rng = np.random.default_rng(seed)
    base = _zipf_probs(vocab)
    trans = []
    for d in range(n_domains):
        # sparse-ish domain-specific bigram: each token strongly predicts a
        # few successors, mixed with the zipf marginal
        nxt = rng.integers(0, vocab, size=(vocab, 4))
        T = np.tile(base, (vocab, 1)) * 0.3
        for j in range(4):
            T[np.arange(vocab), nxt[:, j]] += 0.175
        T /= T.sum(-1, keepdims=True)
        trans.append(T)
    return trans


def lm_stream(vocab: int, n_seqs: int, seq_len: int, *, domain_T=None,
              n_domains: int = 4, seed: int = 0) -> np.ndarray:
    """(n_seqs, seq_len) int32 token sequences from random domains."""
    rng = np.random.default_rng(seed)
    if domain_T is None:
        domain_T = make_bigram_lm(vocab, n_domains, seed=seed + 7)
    base = _zipf_probs(vocab)
    out = np.empty((n_seqs, seq_len), np.int32)
    for i in range(n_seqs):
        T = domain_T[rng.integers(len(domain_T))]
        t = rng.choice(vocab, p=base)
        for s in range(seq_len):
            out[i, s] = t
            t = rng.choice(vocab, p=T[t])
    return out


def make_class_profiles(n_classes: int, vocab: int, sharpness: float = 2.0,
                        seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    z = rng.normal(size=(n_classes, vocab)) * sharpness
    p = np.exp(z - z.max(-1, keepdims=True))
    return p / p.sum(-1, keepdims=True)


def classification(n_classes: int, vocab: int, n_samples: int, seq_len: int,
                   *, profiles: Optional[np.ndarray] = None,
                   labels: Optional[np.ndarray] = None,
                   seed: int = 0) -> Dict[str, np.ndarray]:
    """{'tokens': (N, S) int32, 'labels': (N,) int32}."""
    rng = np.random.default_rng(seed)
    if profiles is None:
        profiles = make_class_profiles(n_classes, vocab, seed=seed + 13)
    if labels is None:
        labels = rng.integers(0, n_classes, size=n_samples).astype(np.int32)
    toks = np.empty((n_samples, seq_len), np.int32)
    for i, c in enumerate(labels):
        toks[i] = rng.choice(vocab, size=seq_len, p=profiles[c])
    return {"tokens": toks, "labels": labels.astype(np.int32)}
