"""Resident-buffer multi-round FL driver (Alg. 1, lines 4-25, over rounds).

PR 1 made a single aggregation call fast; this module removes the per-round
host overhead around it.  The whole round — vmapped local training
(``server.cohort_update``), grafting, trimmed norms and the (M', γ)
accumulation (``flat.aggregate_buffers``) — is ONE jitted program over the
resident ``(N,)`` f32 global buffer and an ``(m, N)`` f32 cohort buffer:

  * clients unpack the global model with ``flat.unflatten`` *inside* the
    trace (a slice + reshape + cast per leaf, fused by XLA),
  * the server side never leaves flat space,
  * both buffers are donated (``donate_argnums=(0, 1)`` with
    ``keep_unused=True`` so the scratch cohort buffer stays a parameter and
    XLA aliases it to the new ``(m, N)`` stacked-updates output), so the two
    allocations ping-pong across rounds instead of being re-allocated.

``run_rounds`` drives R rounds, compiling the round once per cohort shape
(m, batch shapes, attacker presence) and unflattening only at ``eval_every``
boundaries for eval/checkpoint.  This is the layering the next PR shards:
the ``(m, N)`` client axis maps onto the mesh ``data`` axis without
re-plumbing the driver.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core import flat
from repro.core.fedfa import STRATEGIES
from repro.core.server import (ClientSpec, FLConfig, cohort_update,
                               default_class_masks, stack_runtimes)

Params = Dict[str, Any]

# jitted round programs, keyed on everything the trace closes over; the
# FlatIndex participates by identity (the key keeps it alive).  Shapes and
# the cms-is-None structure are handled by jit's own cache underneath.
_ROUND_CACHE: "OrderedDict[Tuple, Any]" = OrderedDict()
_ROUND_CACHE_MAX = 16


def _fl_static(fl: FLConfig) -> Tuple:
    """The FLConfig fields the round trace closes over (FLConfig is mutable,
    so the compiled-program cache keys on a value snapshot)."""
    return (fl.strategy, fl.lr, fl.task, fl.trim, fl.attack_lambda,
            fl.use_kernel, fl.interpret)


def make_flat_round(cfg: ArchConfig, fl: FLConfig, index: flat.FlatIndex,
                    *, any_malicious: bool, donate: bool = True):
    """Build (or fetch) the jitted resident round program.

    Signature of the returned function:
      (g_buf (N,), c_buf (m, N) scratch, masks, gates, gmaps, nd, cms, mal,
       batches, key) -> (g_buf' (N,), x (m, N) stacked updates, mean loss)

    g_buf and c_buf are donated; the new cohort buffer x reuses c_buf's
    allocation and is what the caller donates back next round.
    """
    key = (index, cfg, _fl_static(fl), bool(any_malicious), bool(donate))
    fn = _ROUND_CACHE.get(key)
    if fn is not None:
        _ROUND_CACHE.move_to_end(key)
        return fn
    kw = STRATEGIES[fl.strategy]

    def _round(g_buf, c_buf, masks, gates, gmaps, nd, cms, mal, batches, k):
        m = nd.shape[0]
        g = flat.unflatten(index, g_buf)           # leaf dtypes, inside trace
        keys = jax.random.split(k, m)
        updated, losses = cohort_update(
            g, cfg, fl, masks, gates, batches, cms, mal, keys,
            any_malicious=any_malicious)
        x = flat.flatten_stacked(index, updated)                    # (m, N)
        g_new = flat.aggregate_buffers(
            index, g_buf, x, cfg, masks, gates, gmaps, nd, trim=fl.trim,
            use_kernel=fl.use_kernel, interpret=fl.interpret, **kw)
        return g_new, x, jnp.mean(losses)

    fn = jax.jit(_round, donate_argnums=(0, 1) if donate else (),
                 keep_unused=donate)
    _ROUND_CACHE[key] = fn
    while len(_ROUND_CACHE) > _ROUND_CACHE_MAX:
        _ROUND_CACHE.popitem(last=False)
    return fn


def flat_round(g_buf: jax.Array, c_buf: Optional[jax.Array], cfg: ArchConfig,
               fl: FLConfig, index: flat.FlatIndex, runtimes, batches, key,
               *, any_malicious: bool = False
               ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One resident round: ``flat_round(g_buf, ...) -> (g_buf', c_buf', loss)``.

    runtimes: the ``server.stack_runtimes`` tuple for the selected cohort.
    c_buf may be None (first round of a cohort shape) — a fresh (m, N)
    scratch buffer is allocated; afterwards pass the returned cohort buffer
    back in so its allocation is reused.
    """
    masks, gates, gmaps, nd, cms, mal = runtimes
    m = int(nd.shape[0])
    if c_buf is None or c_buf.is_deleted():
        c_buf = jnp.zeros((m, index.n), jnp.float32)
    cms_in = default_class_masks(cms, cfg, fl, m)
    fn = make_flat_round(cfg, fl, index, any_malicious=any_malicious)
    return fn(g_buf, c_buf, masks, gates, gmaps, nd, cms_in, mal, batches,
              key)


class ResidentDriver:
    """Multi-round driver state: the FlatIndex, per-m scratch cohort buffers,
    and the donated round programs (via the module cache)."""

    def __init__(self, cfg: ArchConfig, fl: FLConfig, index: flat.FlatIndex):
        self.cfg, self.fl, self.index = cfg, fl, index
        self._cbufs: Dict[int, jax.Array] = {}

    def round(self, g_buf: jax.Array, specs: Sequence[ClientSpec], batches,
              key) -> Tuple[jax.Array, jax.Array]:
        """Run one round on the resident buffer: (g_buf', mean loss)."""
        runtimes = stack_runtimes(self.cfg, specs)
        m = len(specs)
        g_buf, c_buf, loss = flat_round(
            g_buf, self._cbufs.get(m), self.cfg, self.fl, self.index,
            runtimes, batches, key,
            any_malicious=any(s.malicious for s in specs))
        self._cbufs[m] = c_buf
        return g_buf, loss


def run_rounds(global_params: Params, cfg: ArchConfig, fl: FLConfig,
               rounds: int, data_fn: Callable[[int], Tuple[Sequence[ClientSpec], Any]],
               key, *, eval_every: int = 5,
               eval_fn: Optional[Callable[[int, float, Params], None]] = None,
               ckpt_path: Optional[str] = None
               ) -> Tuple[Params, List[float]]:
    """Drive R resident rounds; unflatten only at eval/checkpoint boundaries.

    data_fn(r) -> (selected ClientSpecs, stacked client batches) — called
    host-side once per round, exactly like the per-round loop, so client
    selection and batching match ``launch.train.run_fl`` round for round.
    The per-round key is ``jax.random.fold_in(key, r)`` (same as the
    per-round path, so the two drivers are loss-parity comparable).

    eval_fn(r, mean_loss, params_tree) runs at ``eval_every`` boundaries and
    on the final round (``eval_every <= 0``: final round only); with
    ckpt_path set, a checkpoint is written from the resident buffer at the
    same boundaries (``checkpoint.save_from_buffer``).
    Returns (final params tree, per-round mean losses).
    """
    index = flat.get_index(global_params)
    driver = ResidentDriver(cfg, fl, index)
    g_buf = flat.flatten(index, global_params)
    losses: List[jax.Array] = []
    for r in range(rounds):
        specs, batches = data_fn(r)
        g_buf, loss = driver.round(g_buf, specs, batches,
                                   jax.random.fold_in(key, r))
        losses.append(loss)
        if (eval_every > 0 and r % eval_every == 0) or r == rounds - 1:
            if eval_fn is not None:
                eval_fn(r, float(loss), flat.unflatten(index, g_buf))
            if ckpt_path is not None:
                from repro.checkpoint import checkpoint as ckpt_mod
                ckpt_mod.save_from_buffer(
                    f"{ckpt_path}_r{r:05d}", index, g_buf,
                    meta={"round": r, "strategy": fl.strategy})
    return flat.unflatten(index, g_buf), [float(l) for l in losses]
