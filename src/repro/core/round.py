"""Resident-buffer multi-round FL driver (Alg. 1, lines 4-25, over rounds).

PR 1 made a single aggregation call fast; this module removes the per-round
host overhead around it.  The whole round — vmapped local training
(``server.cohort_update``), grafting, trimmed norms and the (M', γ)
accumulation (``flat.aggregate_buffers``) — is ONE jitted program over the
resident ``(N,)`` f32 global buffer and an ``(m, N)`` f32 cohort buffer:

  * clients unpack the global model with ``flat.unflatten`` *inside* the
    trace (a slice + reshape + cast per leaf, fused by XLA),
  * the server side never leaves flat space,
  * both buffers are donated (``donate_argnums=(0, 1)`` with
    ``keep_unused=True`` so the scratch cohort buffer stays a parameter and
    XLA aliases it to the new ``(m, N)`` stacked-updates output), so the two
    allocations ping-pong across rounds instead of being re-allocated.

``run_rounds`` drives R rounds, compiling the round once per cohort shape
(m, batch shapes, attacker presence) and unflattening only at ``eval_every``
boundaries for eval/checkpoint.

With a mesh (``mesh=`` on ``run_rounds``/``ResidentDriver``/``flat_round``,
built by ``repro.launch.mesh.get_mesh``), the round is 2-D SPMD over the
``(data, model)`` axes (``repro.sharding.cohort``):

  * the ``(m, N)`` client axis is sharded over ``data`` — local training
    runs data-parallel over client shards; uneven cohorts are padded
    host-side with inert ``n_data = 0`` rows,
  * the ``(N,)`` parameter axis of both RESIDENT buffers is sharded over
    ``model`` — the global buffer lives as P("model") and the donated
    cohort scratch as P("data", "model"), each device keeping only its
    N/n_model slice between rounds (N is padded to a multiple of the model
    shards by ``flat.FlatIndex``, with an inert zero tail).

Inside the round the global model is (unavoidably) gathered once into
local training; the graft gather consumes the freshly trained cohort in
the pre-split P("data") layout (a data-dependent cross-shard row
permutation needs whole rows), and from there the N axis splits EARLY:
the distributed two-stage trimmed quantile
(``kernels.fedfa_quantile.multilevel``) runs the norms pass on
P("data", "model") slices — per-level histogram psums over ``model``,
never whole rows — and both (M', γ) reductions are per-shard partial
sums finished by an N/n_model-sized psum over ``data`` (no
reduce-scatter; ``kernels.fedfa_agg.ops.accumulate``).  The γ = 0 merge
runs on the slices, and the returned cohort buffer is constrained back
to the 2-D layout by a communication-free local slice.  The aggregation
path lowers with zero all-gathers; ``flat.unflatten`` re-gathers the
global buffer only at eval/checkpoint boundaries.  The donated
ping-pong of the two buffers is unchanged (matching in/out shardings
keep XLA aliasing them).

Slot-pool / donation contract (shared with ``repro.core.async_round``):
the (m, N) cohort scratch is a **slot pool** — m fixed rows whose content
is meaningful only where the per-row weight (``n_data``, or the async
engine's staleness-discounted weight) is positive; zero-weight rows are
inert in every reduction and in α, which is what makes partial cohorts,
mesh padding and partially-filled async pools exact.  The buffer's
*values* are never an input to a round program (``keep_unused=True``
keeps it a parameter solely so XLA aliases its allocation to the new
stacked-updates output), so any (m, N) f32 buffer of the right sharding
can be donated in, and the returned buffer must be treated as consumed
scratch: hand it back to the next program that writes all of its live
rows (the resident round overwrites every row; the async admit program
scatters into its dispatch slots and preserves the rest).  Per cohort
shape there is exactly ONE live scratch buffer — ``ResidentDriver`` keys
its pool on the PADDED row count so cohorts that pad to the same shape
ping-pong one allocation.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core import flat
from repro.core.fedfa import STRATEGIES
from repro.core.server import (ClientSpec, FLConfig, cohort_update,
                               default_class_masks, stack_runtimes)
from repro.sharding import cohort as cohort_sh

Params = Dict[str, Any]

# jitted round programs, keyed on everything the trace closes over; the
# FlatIndex participates by identity (the key keeps it alive).  Shapes and
# the cms-is-None structure are handled by jit's own cache underneath.
_ROUND_CACHE: "OrderedDict[Tuple, Any]" = OrderedDict()
_ROUND_CACHE_MAX = 16


def _fl_static(fl: FLConfig) -> Tuple:
    """The FLConfig fields the round trace closes over (FLConfig is mutable,
    so the compiled-program cache keys on a value snapshot).  The cohort
    admission dtype participates: an int8 and an f32 round of the same
    cohort shape are different programs with different buffer dtypes, and a
    key that omitted it would hand one the other's compiled round."""
    return (fl.strategy, fl.lr, fl.task, fl.trim, fl.attack_lambda,
            fl.use_kernel, fl.interpret, getattr(fl, "update_dtype", "f32"))


def eval_boundary(r: int, rounds: int, eval_every: int) -> bool:
    """True on rounds where eval/checkpoint fire: every ``eval_every``
    rounds AND on the final round; ``eval_every <= 0`` means final round
    only.  Note the predicate deliberately fires at r = 0 (``0 % k == 0``)
    so a fresh run logs a baseline point before any training signal —
    callers that want training-only curves should skip r = 0 themselves.
    One shared helper so the resident driver, the async engine and the
    per-round loop in ``launch.train`` cannot drift."""
    return (eval_every > 0 and r % eval_every == 0) or r == rounds - 1


def _mesh_key(mesh) -> Optional[Tuple]:
    """Value key for a mesh: reconstructing an identical mesh (same device
    ids, axis names, shape) must hit the round cache instead of recompiling
    every cohort shape — Mesh object identity is not stable across
    ``make_mesh`` calls."""
    if mesh is None:
        return None
    return (tuple(mesh.axis_names), tuple(mesh.devices.shape),
            tuple(d.id for d in mesh.devices.flat))


def _round_key(cfg: ArchConfig, fl: FLConfig, index: flat.FlatIndex, *,
               any_malicious: bool, donate: bool = True, mesh=None,
               m_real: Optional[int] = None) -> Tuple:
    """The ``_ROUND_CACHE`` key of one resident round program — everything
    the trace closes over.  Exposed so ``repro.analysis.passes
    .check_cache_keys`` can probe that mesh/pad/row-count variations map
    to DISTINCT keys (the PR 5/6 bug class was keys missing one of these
    dimensions)."""
    return (index, cfg, _fl_static(fl), bool(any_malicious), bool(donate),
            _mesh_key(mesh), m_real)


def round_contract(index: flat.FlatIndex, mesh=None, *, rows: int):
    """The resident round program's declared contract (see
    ``repro.analysis.contracts``), for a cohort padded to ``rows``.

    Always: the full (rows, N) cohort is never all-gathered, both
    resident buffers (params 0 = g_buf, 1 = cohort scratch) must have
    materialized donation aliases (the ping-pong), and the statically
    estimated per-device peak live bytes stay within a budget of
    ``(6 + 12*r) * N * 4`` where r is the per-data-shard row count —
    the resident state plus the vmapped training temporaries (grads,
    optimizer state, re-layout copies), measured ~11-16 N-multiples on
    the canonical fixture, with ~1.6x headroom.  A dropped donation or
    an accidentally materialized cohort replica blows the budget.

    On a multi-device data-only mesh the round has NO legitimate
    all-gather at all and the (M', γ) partial sums show up as >= 1
    N-sized all-reduce.  With model shards the strict communication
    bounds live on the aggregation path contract
    (``kernels.fedfa_agg.ops.accumulate_contract``); the *training*-side
    re-layout collectives GSPMD emits over the idle model axis are now
    bounded too (the PR 7 follow-up (c) — ``analysis/blame`` attributes
    them to the segment concatenates in ``flat.py``, the mask
    multiplies in ``masking.py`` and the optimizer all-to-alls): the
    measured inventory on the canonical 2x2 fixture is 38 all-gathers /
    24 all-to-alls / 12 collective-permutes, ceilinged at ~1.7x, and no
    single all-gather may exceed one full (N,) model row — a
    cohort-sized gather stays structurally impossible.  Since the
    distributed two-stage quantile landed, the aggregation tail has NO
    reduce-scatter either (the N axis pre-splits before the reductions);
    a small allowance remains for the re-layout ops GSPMD may still emit
    on the training side.
    """
    from repro.analysis.contracts import Contract
    multi = mesh is not None and mesh.size > 1
    ms = cohort_sh.model_shards(mesh)
    r = max(1, rows // cohort_sh.data_shards(mesh))
    kw: Dict[str, Any] = {}
    if multi and ms == 1:
        kw = dict(all_gathers=0, scale_allreduces=(1, None),
                  scale_elems=index.n_padded)
    elif multi:
        kw = dict(all_gathers=(None, 64), all_to_alls=(None, 48),
                  collective_permutes=(None, 24), reduce_scatters=(0, 8),
                  max_all_gather_elems=index.n_padded)
    return Contract(
        name=f"round/ms{ms}",
        description="resident round: donated ping-pong, no cohort gather",
        full_cohort_gathers=0, cohort_elems=rows * index.n_padded,
        peak_live_bytes_per_device=(None, (6 + 12 * r) * index.n_padded * 4),
        donated=frozenset({0, 1}), **kw)


def quantized_round_contract(index: flat.FlatIndex, mesh=None, *, rows: int):
    """Declared contract of the QUANTIZED resident round (``--update-dtype
    int8``/``bf16``; canonical report on the data-parallel mesh).

    Same structural guarantees as ``round_contract`` — no full-cohort
    gather, donated ping-pong of every resident buffer (g_buf + the
    quantized cohort/scale/error-feedback pools, params 0-4), zero
    all-gathers with >= 1 N-sized partial-sum all-reduce on a data mesh —
    plus the quantization-specific ones, checked on a standalone trace of
    the fused dequantize-accumulate (``agg_ops.accumulate_quant``):
    exactly 1 read of the quantized rows, 0 sorts, and because the rows
    enter the kernel in their admitted dtype there is no materialized f32
    (m, N) dequant transient.  Peak budget ``(6 + 10r) * N * 4``
    bytes/device: the RESIDENT inter-round pools drop ~4x (2 int8 (m, N)
    pools + 2 small scale tables vs one f32 (m, N) scratch) and the
    aggregation path reads int8 rows, but the in-program transient peak
    is a little above the f32 round's measurement — the f32 training
    rows can no longer alias into the (now int8) donated pool, and the
    error-feedback dequant + requantize chain keeps one extra f32 (m, N)
    tenant — measured 14.0 N-multiples at r = 1 on the canonical
    4-device fixture vs 11.0 for the f32 round (whose looser budget is
    ``(6 + 12r)``).
    """
    from repro.analysis.contracts import Contract
    multi = mesh is not None and mesh.size > 1
    kw: Dict[str, Any] = {}
    if multi:
        kw = dict(all_gathers=0, reduce_scatters=0,
                  scale_allreduces=(1, None), scale_elems=index.n_padded)
    r = max(1, rows // cohort_sh.data_shards(mesh))
    return Contract(
        name="round/quant",
        description="quantized round: int8 admission, fused dequantize",
        full_cohort_gathers=0, cohort_elems=rows * index.n_padded,
        peak_live_bytes_per_device=(None, (6 + 10 * r) * index.n_padded * 4),
        donated=frozenset({0, 1, 2, 3, 4}), row_reads=1, sorts=0, **kw)


def make_flat_round(cfg: ArchConfig, fl: FLConfig, index: flat.FlatIndex,
                    *, any_malicious: bool, donate: bool = True,
                    mesh=None, m_real: Optional[int] = None):
    """Build (or fetch) the jitted resident round program.

    Signature of the returned function:
      (g_buf (N,), c_buf (m, N) scratch, masks, gates, gmaps, nd, cms, mal,
       batches, keys (m, ...)) -> (g_buf' (N,), x (m, N) updates, mean loss)

    g_buf and c_buf are donated; the new cohort buffer x reuses c_buf's
    allocation and is what the caller donates back next round.

    ``keys`` are the per-client PRNG keys, split HOST-side by the caller
    (``flat_round``): splitting inside the traced program is not safe under
    a mesh — GSPMD may partition the threefry computation differently per
    mesh shape, changing the malicious label-shuffle bits (observed on
    (data, model) meshes) — and host-side keys match the per-round
    ``server.fl_round`` bit-for-bit.

    With ``mesh`` set the program carries explicit in/out shardings: the
    cohort-stacked arguments (keys, and x) over the mesh ``data`` axis,
    g_buf over ``model``, c_buf/x over ``(data, model)``, loss replicated.
    ``m_real`` (static) marks the number of real rows of a padded cohort —
    the reported loss averages over those only (pad rows are already inert
    in aggregation via ``n_data = 0``).
    """
    key = _round_key(cfg, fl, index, any_malicious=any_malicious,
                     donate=donate, mesh=mesh, m_real=m_real)
    fn = _ROUND_CACHE.get(key)
    if fn is not None:
        _ROUND_CACHE.move_to_end(key)
        return fn
    kw = STRATEGIES[fl.strategy]

    if fl.update_dtype != "f32":
        import functools
        do_graft = bool(kw.get("graft", False))
        dens_fn = jax.vmap(functools.partial(flat._density_and_fraction,
                                             cfg, index))

        def _round_q(g_buf, c_buf, s_buf, e_buf, es_buf, masks, gates,
                     gmaps, nd, cms, mal, batches, keys):
            g = flat.unflatten(index, g_buf)
            updated, losses = cohort_update(
                g, cfg, fl, masks, gates, batches, cms, mal, keys,
                any_malicious=any_malicious)
            x = cohort_sh.constrain_cohort(
                flat.flatten_stacked(index, updated), mesh)         # (m, N)
            if do_graft:
                x = cohort_sh.constrain_cohort(
                    jax.vmap(functools.partial(flat._graft_flat, index))(
                        x, gmaps), mesh)
            dens, _ = dens_fn(masks)
            # server-side error feedback: the residual of the PREVIOUS
            # quantized admission of this dispatch slot re-enters before
            # quantizing, so compression noise averages out across rounds
            # instead of biasing the trimmed mean.  The density mask wraps
            # the WHOLE sum: a slot's next occupant may cover a narrower
            # width, and residual components outside its mask must not
            # leak values into coordinates whose density (and hence γ
            # weight) is 0 — the stored rows stay in the client subspace
            y = (x + flat.dequantize_cohort(index, e_buf, es_buf)) \
                * cohort_sh.constrain_cohort(dens, mesh)
            x_q, scales = flat.quantize_cohort(index, y, fl.update_dtype)
            e = y - flat.dequantize_cohort(index, x_q, scales)
            e_q, e_s = flat.quantize_cohort(index, e, fl.update_dtype)
            g_new = flat.aggregate_buffers(
                index, g_buf, cohort_sh.constrain_cohort_buffer(x_q, mesh),
                cfg, masks, gates, gmaps, nd, trim=fl.trim, scales=scales,
                pregrafted=True, use_kernel=fl.use_kernel,
                interpret=fl.interpret, mesh=mesh, **kw)
            loss = jnp.mean(losses if m_real is None else losses[:m_real])
            return (g_new, cohort_sh.constrain_cohort_buffer(x_q, mesh),
                    scales, cohort_sh.constrain_cohort_buffer(e_q, mesh),
                    e_s, loss)

        jit_kw = {}
        if mesh is not None:
            jit_kw["in_shardings"], jit_kw["out_shardings"] = \
                cohort_sh.quantized_round_shardings(mesh)
        fn = jax.jit(_round_q,
                     donate_argnums=(0, 1, 2, 3, 4) if donate else (),
                     keep_unused=donate, **jit_kw)
        _ROUND_CACHE[key] = fn
        while len(_ROUND_CACHE) > _ROUND_CACHE_MAX:
            _ROUND_CACHE.popitem(last=False)
        return fn

    def _round(g_buf, c_buf, masks, gates, gmaps, nd, cms, mal, batches,
               keys):
        g = flat.unflatten(index, g_buf)           # leaf dtypes, inside trace
        updated, losses = cohort_update(
            g, cfg, fl, masks, gates, batches, cms, mal, keys,
            any_malicious=any_malicious)
        # the graft gather consumes x in the pre-split P("data") layout
        # (data-dependent row permutation needs whole rows); the norms and
        # reductions split N immediately after, and the RETURNED cohort
        # buffer is sliced down to the resident 2-D P("data", "model")
        # layout for free
        x = cohort_sh.constrain_cohort(
            flat.flatten_stacked(index, updated), mesh)             # (m, N)
        g_new = flat.aggregate_buffers(
            index, g_buf, x, cfg, masks, gates, gmaps, nd, trim=fl.trim,
            use_kernel=fl.use_kernel, interpret=fl.interpret, mesh=mesh, **kw)
        loss = jnp.mean(losses if m_real is None else losses[:m_real])
        return g_new, cohort_sh.constrain_cohort_buffer(x, mesh), loss

    jit_kw = {}
    if mesh is not None:
        jit_kw["in_shardings"], jit_kw["out_shardings"] = \
            cohort_sh.round_shardings(mesh)
    fn = jax.jit(_round, donate_argnums=(0, 1) if donate else (),
                 keep_unused=donate, **jit_kw)
    _ROUND_CACHE[key] = fn
    while len(_ROUND_CACHE) > _ROUND_CACHE_MAX:
        _ROUND_CACHE.popitem(last=False)
    return fn


def _quant_state_ok(st, m: int, want) -> bool:
    """Is ``st`` a live quantized cohort state tuple for m rows of dtype
    ``want``?  (x_q, scales, e_buf, e_scales) — all four must be undeleted
    device arrays of the matching shape/dtype."""
    return (isinstance(st, tuple) and len(st) == 4
            and not any(b.is_deleted() for b in st)
            and st[0].shape[0] == m and st[0].dtype == want)


def fresh_quant_state(index: flat.FlatIndex, m: int, update_dtype: str):
    """Zero-initialized quantized cohort state: (x_q, scales, e_buf,
    e_scales).  Zero error-feedback pools are exact no-ops on the first
    round (scale 0 dequantizes to zeros)."""
    want = flat.update_dtype_of(update_dtype)
    S = index.n_segments
    return (jnp.zeros((m, index.n_padded), want),
            jnp.zeros((m, S), jnp.float32),
            jnp.zeros((m, index.n_padded), want),
            jnp.zeros((m, S), jnp.float32))


def flat_round(g_buf: jax.Array, c_buf, cfg: ArchConfig,
               fl: FLConfig, index: flat.FlatIndex, runtimes, batches, key,
               *, any_malicious: bool = False, mesh=None
               ) -> Tuple[jax.Array, Any, jax.Array]:
    """One resident round: ``flat_round(g_buf, ...) -> (g_buf', c_buf', loss)``.

    runtimes: the ``server.stack_runtimes`` tuple for the selected cohort.
    c_buf may be None (first round of a cohort shape) — a fresh (m, N)
    scratch buffer is allocated; afterwards pass the returned cohort buffer
    back in so its allocation is reused.  With a quantized admission dtype
    (``fl.update_dtype`` int8/bf16) the cohort state is the TUPLE
    (x_q, scales, e_buf, e_scales) — quantized rows, their per-segment
    scales, and the error-feedback residual pools — donated and returned
    as a unit.

    With ``mesh`` set the cohort axis is sharded over the mesh ``data``
    axis; a cohort whose m doesn't divide the data-shard count is padded
    host-side with inert rows (``sharding.cohort.pad_cohort``), so the
    returned cohort buffer has the padded row count.
    """
    masks, gates, gmaps, nd, cms, mal = runtimes
    m = int(nd.shape[0])
    m_real = None
    pad = cohort_sh.pad_rows(m, mesh)
    if pad:
        (masks, gates, gmaps, nd, cms, mal), batches = cohort_sh.pad_cohort(
            runtimes, batches, pad)
        m_real, m = m, m + pad
    qmode = fl.update_dtype != "f32"
    if qmode:
        if not _quant_state_ok(c_buf, m, flat.update_dtype_of(
                fl.update_dtype)):
            c_buf = fresh_quant_state(index, m, fl.update_dtype)
    elif c_buf is None or isinstance(c_buf, tuple) \
            or c_buf.is_deleted() or c_buf.shape[0] != m:
        c_buf = jnp.zeros((m, index.n_padded), jnp.float32)
    cms_in = default_class_masks(cms, cfg, fl, m)
    # split per-client keys HOST-side (see make_flat_round), for the REAL
    # rows only: padded cohorts must hand row i the same key the unpadded
    # cohort would (the malicious label-shuffle consumes it), so pad rows
    # reuse key 0
    keys = jax.random.split(key, m if m_real is None else m_real)
    if m_real is not None and m > m_real:
        keys = jnp.concatenate(
            [keys, jnp.broadcast_to(keys[:1],
                                    (m - m_real,) + keys.shape[1:])])
    fn = make_flat_round(cfg, fl, index, any_malicious=any_malicious,
                         mesh=mesh, m_real=m_real)
    if qmode:
        g_buf, x_q, scales, e_q, e_s, loss = fn(
            g_buf, *c_buf, masks, gates, gmaps, nd, cms_in, mal, batches,
            keys)
        return g_buf, (x_q, scales, e_q, e_s), loss
    return fn(g_buf, c_buf, masks, gates, gmaps, nd, cms_in, mal, batches,
              keys)


class ResidentDriver:
    """Multi-round driver state: the FlatIndex, per-shape scratch cohort
    buffers, the optional mesh, and the donated round programs (via the
    module cache).

    The scratch pool is keyed on the PADDED row count (``m +
    sharding.cohort.pad_rows(m, mesh)``) — the shape the buffer actually
    has — not the raw cohort size: under a mesh, distinct real sizes that
    pad to the same row count must ping-pong ONE allocation (keying on
    ``len(specs)`` held a separate, never-donated buffer per real size and
    kept dead donated buffers referenced).  The key ALSO carries the
    cohort admission dtype: an int8 and an f32 cohort of the same padded
    shape are different states (different buffer dtypes, and the quantized
    one is a (x_q, scales, e_buf, e_scales) tuple) and must never collide
    on one pool slot."""

    def __init__(self, cfg: ArchConfig, fl: FLConfig, index: flat.FlatIndex,
                 mesh=None):
        self.cfg, self.fl, self.index, self.mesh = cfg, fl, index, mesh
        self._cbufs: Dict[Tuple[int, str], Any] = {}

    def round(self, g_buf: jax.Array, specs: Sequence[ClientSpec], batches,
              key) -> Tuple[jax.Array, jax.Array]:
        """Run one round on the resident buffer: (g_buf', mean loss)."""
        runtimes = stack_runtimes(self.cfg, specs)
        m = len(specs)
        m_rows = m + cohort_sh.pad_rows(m, self.mesh)
        pool_key = (m_rows, self.fl.update_dtype)
        g_buf, c_buf, loss = flat_round(
            g_buf, self._cbufs.get(pool_key), self.cfg, self.fl, self.index,
            runtimes, batches, key, mesh=self.mesh,
            any_malicious=any(s.malicious for s in specs))
        self._cbufs[pool_key] = c_buf
        # evict entries whose buffer was donated elsewhere (e.g. handed to
        # the async engine) — a deleted jax.Array is dead weight that would
        # otherwise stay referenced forever
        dead = lambda v: (any(b.is_deleted() for b in v)
                          if isinstance(v, tuple) else v.is_deleted())
        for k in [k for k, v in self._cbufs.items() if dead(v)]:
            del self._cbufs[k]
        return g_buf, loss


def run_rounds(global_params: Params, cfg: ArchConfig, fl: FLConfig,
               rounds: int, data_fn: Callable[[int], Tuple[Sequence[ClientSpec], Any]],
               key, *, eval_every: int = 5,
               eval_fn: Optional[Callable[[int, float, Params], None]] = None,
               ckpt_path: Optional[str] = None, mesh=None
               ) -> Tuple[Params, List[float]]:
    """Drive R resident rounds; unflatten only at eval/checkpoint boundaries.

    data_fn(r) -> (selected ClientSpecs, stacked client batches) — called
    host-side once per round, exactly like the per-round loop, so client
    selection and batching match ``launch.train.run_fl`` round for round.
    The per-round key is ``jax.random.fold_in(key, r)`` (same as the
    per-round path, so the two drivers are loss-parity comparable).

    eval_fn(r, mean_loss, params_tree) runs at ``eval_boundary`` rounds
    (every ``eval_every`` rounds including r = 0, plus the final round;
    ``eval_every <= 0``: final round only); with ckpt_path set, a
    checkpoint is written from the resident buffer at the same boundaries
    (``checkpoint.save_from_buffer``).
    Returns (final params tree, per-round mean losses).  ``rounds <= 0``
    returns the input params untouched without flattening or compiling
    anything, so scripted sweeps can no-op cleanly.
    """
    if rounds <= 0:
        return global_params, []
    index = flat.get_index(global_params, pad_to=cohort_sh.pad_unit(mesh))
    driver = ResidentDriver(cfg, fl, index, mesh=mesh)
    g_buf = flat.flatten(index, global_params)
    if mesh is not None:
        # place the global buffer on its model-sharded layout up front so
        # the first round's donation isn't defeated by an implicit reshard
        g_buf = jax.device_put(g_buf, cohort_sh.global_sharding(mesh))
    # losses convert to host floats INCREMENTALLY, one round behind the
    # dispatch (converting round r-1 while round r is in flight keeps the
    # async-dispatch pipeline full but pins at most ONE device scalar,
    # instead of retaining all R per-round device arrays until the end)
    losses: List[float] = []
    pending_loss: Optional[jax.Array] = None
    for r in range(rounds):
        specs, batches = data_fn(r)
        g_buf, loss = driver.round(g_buf, specs, batches,
                                   jax.random.fold_in(key, r))
        if pending_loss is not None:
            losses.append(float(pending_loss))
        pending_loss = loss
        if eval_boundary(r, rounds, eval_every):
            if eval_fn is not None:
                eval_fn(r, float(loss), flat.unflatten(index, g_buf))
            if ckpt_path is not None:
                from repro.checkpoint import checkpoint as ckpt_mod
                ckpt_mod.save_from_buffer(
                    f"{ckpt_path}_r{r:05d}", index, g_buf,
                    meta={"round": r, "strategy": fl.strategy})
    losses.append(float(pending_loss))
    return flat.unflatten(index, g_buf), losses
