from repro.core import fedfa, masking, client, server, attacks, nas
