"""Federated server: round orchestration (Alg. 1 lines 4-25).

The round is one SPMD program: selected clients' runtimes (width masks,
depth gates, graft maps, data counts, class masks, malicious flags) are
stacked along a leading client axis, local training is vmapped over it, and
the flat engine reduces over it.  The resident driver
(``repro.core.round``) shards that client axis over the mesh ``data`` axis
when given a mesh (``repro.sharding.cohort`` builds the NamedShardings;
``launch/train.py --mesh`` threads it through); the per-round path here
runs unsharded.
"""
from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core import attacks as attacks_mod
from repro.core import fedfa
from repro.core.client import local_update
from repro.models.masks import (ClientArch, WidthMasks, full_client,
                                max_section_depths, stack_masks)

Params = Dict[str, Any]


@dataclass
class ClientSpec:
    arch: ClientArch
    n_data: int
    malicious: bool = False
    class_mask: Optional[np.ndarray] = None   # (V,) non-IID logit zeroing


@dataclass
class FLConfig:
    participation: float = 0.1          # C
    local_steps: int = 5                # E (steps == epochs on synthetic data)
    lr: float = 0.01
    attack_lambda: float = 1.0          # λ in Eq. 1
    strategy: str = "fedfa"
    task: str = "lm"
    trim: float = 0.95
    agg_engine: str = "flat"            # "flat" (fused buffer) | "tree"
    use_kernel: Optional[bool] = None   # flat engine: Pallas kernels (None=auto)
    interpret: bool = False             # flat engine: interpret-mode kernels
    update_dtype: str = "f32"           # cohort admission dtype: f32|bf16|int8
    seed: int = 0


def select_clients(n_clients: int, frac: float, rng: np.random.Generator) -> np.ndarray:
    m = max(1, int(round(frac * n_clients)))
    return rng.choice(n_clients, size=m, replace=False)


_RUNTIME_CACHE: "OrderedDict[Tuple[ArchConfig, Any], Tuple]" = OrderedDict()
_RUNTIME_CACHE_MAX = 256


def _arch_runtime(cfg: ArchConfig, arch) -> Tuple:
    """Memoized (masks, gates, graft map) for one (cfg, arch) — ClientSpec
    architectures repeat across rounds, so cohort assembly shouldn't rebuild
    the same host-side device arrays every round.  LRU-bounded like
    ``flat._INDEX_CACHE``."""
    key = (cfg, arch)
    hit = _RUNTIME_CACHE.get(key)
    if hit is None:
        hit = _RUNTIME_CACHE[key] = (arch.masks(cfg), arch.gates(cfg),
                                     arch.graft(cfg))
        while len(_RUNTIME_CACHE) > _RUNTIME_CACHE_MAX:
            _RUNTIME_CACHE.popitem(last=False)
    else:
        _RUNTIME_CACHE.move_to_end(key)
    return hit


def stack_runtimes(cfg: ArchConfig, specs: Sequence[ClientSpec]):
    per_arch = [_arch_runtime(cfg, s.arch) for s in specs]
    masks = stack_masks([t[0] for t in per_arch])
    gates = jnp.stack([t[1] for t in per_arch])
    gmaps = jnp.stack([t[2] for t in per_arch])
    nd = jnp.asarray([float(s.n_data) for s in specs], jnp.float32)
    cms = None
    if any(s.class_mask is not None for s in specs):
        V = cfg.padded_vocab
        cms = jnp.stack([
            jnp.asarray(s.class_mask if s.class_mask is not None
                        else np.ones(V, np.float32)) for s in specs])
    mal = jnp.asarray([s.malicious for s in specs], jnp.float32)
    return masks, gates, gmaps, nd, cms, mal


# constant across rounds — cached so the resident round path doesn't
# re-allocate an (m, V) device array every round.  A plain dict (not
# lru_cache) keyed ALSO on the active backend, with deleted-array checks:
# a process-global lru_cache leaked stale-backend device arrays across
# forced-device-count subprocesses and mesh teardowns.
_MASK_CACHE: Dict[Tuple[int, int, str], jax.Array] = {}


def _ones_class_masks(m: int, vocab: int) -> jax.Array:
    key = (m, vocab, jax.default_backend())
    hit = _MASK_CACHE.get(key)
    if hit is None or hit.is_deleted():
        hit = _MASK_CACHE[key] = jnp.ones((m, vocab), jnp.float32)
    return hit


def clear_runtime_caches() -> None:
    """Drop every cached device array this module holds (the per-arch
    runtime tuples and the all-ones class masks).  Test fixtures call this
    between backend/mesh reconfigurations so arrays from a torn-down
    backend can't leak into the next test."""
    _MASK_CACHE.clear()
    _RUNTIME_CACHE.clear()


def default_class_masks(cms: Optional[jax.Array], cfg: ArchConfig,
                        fl: FLConfig, m: int) -> Optional[jax.Array]:
    """Stacked class masks for vmapped training: all-ones on the cls task when
    no client restricts its classes, None on tasks without class masking."""
    if cms is not None:
        return cms
    return _ones_class_masks(m, cfg.padded_vocab) if fl.task == "cls" else None


def cohort_update(global_params: Params, cfg: ArchConfig, fl: FLConfig,
                  masks: WidthMasks, gates: jax.Array, client_batches,
                  cms: Optional[jax.Array], mal: jax.Array, keys: jax.Array,
                  *, any_malicious: bool) -> Tuple[Params, jax.Array]:
    """Vmapped local training over the stacked cohort (Alg. 1 lines 7-10),
    including the malicious label-shuffle branch when the cohort has
    attackers.  Shared by the per-round path (``fl_round``) and the resident
    flat driver (``repro.core.round``).  Returns (stacked updated params with
    leading client axis m, (m,) mean local losses)."""

    def train_one(mk, gt, batches, cm, mal_flag, k):
        honest, losses = local_update(
            global_params, cfg, batches, masks=mk, gates=gt, lr=fl.lr,
            task=fl.task, class_mask=cm, optimizer=cfg.optimizer,
            momentum=cfg.momentum, weight_decay=cfg.weight_decay)
        if any_malicious:
            poisoned = attacks_mod.shuffle_labels(batches, k, fl.task)
            bad, _ = local_update(
                global_params, cfg, poisoned, masks=mk, gates=gt, lr=fl.lr,
                task=fl.task, class_mask=cm, optimizer=cfg.optimizer,
                momentum=cfg.momentum, weight_decay=cfg.weight_decay)
            attacked = attacks_mod.combine_malicious(
                global_params, honest, bad, fl.attack_lambda)
            out = jax.tree.map(
                lambda h, a: jnp.where(mal_flag > 0, a, h), honest, attacked)
        else:
            out = honest
        return out, jnp.mean(losses)

    if cms is None:
        return jax.vmap(
            lambda mk, gt, b, fl_, k: train_one(mk, gt, b, None, fl_, k)
        )(masks, gates, client_batches, mal, keys)
    return jax.vmap(train_one)(masks, gates, client_batches, cms, mal, keys)


def fl_round(global_params: Params, cfg: ArchConfig, fl: FLConfig,
             specs: Sequence[ClientSpec], client_batches, key,
             *, any_malicious: Optional[bool] = None) -> Tuple[Params, jax.Array]:
    """One synchronized round over the given (already selected) clients.

    client_batches: pytree with leading axes (m, E, B, ...) — per-client
    local datasets for E local steps.  Returns (new_global, mean local loss).
    """
    masks, gates, gmaps, nd, cms, mal = stack_runtimes(cfg, specs)
    if any_malicious is None:
        any_malicious = any(s.malicious for s in specs)

    m = nd.shape[0]
    keys = jax.random.split(key, m)
    cms_in = default_class_masks(cms, cfg, fl, m)
    updated, losses = cohort_update(
        global_params, cfg, fl, masks, gates, client_batches, cms_in, mal,
        keys, any_malicious=any_malicious)

    new_global = fedfa.aggregate_strategy(
        fl.strategy, global_params, updated, cfg, masks, gates, gmaps, nd,
        trim=fl.trim, engine=fl.agg_engine, use_kernel=fl.use_kernel,
        interpret=fl.interpret)
    return new_global, jnp.mean(losses)


def fl_round_flat(g_buf: jax.Array, cfg: ArchConfig, fl: FLConfig,
                  specs: Sequence[ClientSpec], client_batches, key,
                  *, index=None, c_buf: Optional[jax.Array] = None,
                  any_malicious: Optional[bool] = None, mesh=None):
    """Flat-native counterpart of ``fl_round``: one round on the resident
    (N,) global buffer, sharing ``stack_runtimes`` with the per-round path.

    Dispatches to the donated, jitted round program in ``repro.core.round``
    (compiled once per cohort shape).  Returns (new g_buf, new (m, N) cohort
    buffer to donate back next round, mean local loss).  For multi-round
    training prefer ``repro.core.round.run_rounds``, which also manages the
    scratch cohort buffers.
    """
    from repro.core import round as round_mod
    if index is None:
        raise ValueError("fl_round_flat needs the FlatIndex the resident "
                         "buffer was flattened with (flat.get_index(params))")
    runtimes = stack_runtimes(cfg, specs)
    if any_malicious is None:
        any_malicious = any(s.malicious for s in specs)
    return round_mod.flat_round(g_buf, c_buf, cfg, fl, index, runtimes,
                                client_batches, key, mesh=mesh,
                                any_malicious=any_malicious)


# ---------------------------------------------------------------------------
# Scenario helpers (paper §5.1 experimental setup)
# ---------------------------------------------------------------------------

def make_client_specs(cfg: ArchConfig, n_clients: int, *,
                      archs: Sequence[ClientArch],
                      malicious_frac: float = 0.0,
                      n_data_range: Tuple[int, int] = (100, 250),
                      class_masks: Optional[Sequence[np.ndarray]] = None,
                      seed: int = 0) -> List[ClientSpec]:
    """Half the clients take the smallest architecture (paper §5.1), the
    rest get the supplied (e.g. NAS-chosen) architectures; attackers use the
    largest architecture (paper §3.1).  ``n_data_range`` is INCLUSIVE on
    both ends — the paper's 100-250 samples means 250 is drawable."""
    rng = np.random.default_rng(seed)
    smallest = min(archs, key=lambda a: (a.width_mult, sum(a.section_depths)))
    n_mal = int(round(malicious_frac * n_clients))
    mal_ids = set(rng.choice(n_clients, size=n_mal, replace=False).tolist()) \
        if n_mal else set()
    specs = []
    for i in range(n_clients):
        if i in mal_ids:
            arch = full_client(cfg)                    # largest architecture
        elif i % 2 == 0:
            arch = smallest
        else:
            arch = archs[int(rng.integers(len(archs)))]
        specs.append(ClientSpec(
            arch=arch,
            n_data=int(rng.integers(*n_data_range, endpoint=True)),
            malicious=i in mal_ids,
            class_mask=None if class_masks is None else class_masks[i]))
    return specs
