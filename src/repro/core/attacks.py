"""Backdoor attack model (paper §3.1, Eq. 1).

ΔM_malicious = ΔM_c + λ·ΔM_backdoor — the malicious client submits its
honest update plus λ times a backdoor delta obtained by training on
label-shuffled data (paper §5.1: "random shuffling of the data labels").
Attackers select the *largest* architecture (paper §3.1), which is why
incomplete aggregation is exploitable and grafting closes the hole.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


def shuffle_labels(batches, key, task: str = "lm"):
    """Poisoned copy of the local batches with permuted labels."""
    if task == "cls":
        labels = batches["labels"]                     # (E, B)
        flat = labels.reshape(-1)
        perm = jax.random.permutation(key, flat.shape[0])
        return dict(batches, labels=flat[perm].reshape(labels.shape))
    toks = batches["tokens"]                           # (E, B, S)
    flat = toks.reshape(-1)
    perm = jax.random.permutation(key, flat.shape[0])
    return dict(batches, tokens=flat[perm].reshape(toks.shape))


def combine_malicious(global_params: Params, honest: Params,
                      backdoored: Params, lam: float) -> Params:
    """M_global + ΔM_c + λ·ΔM_backdoor (Eq. 1)."""
    def f(g, h, b):
        gf = g.astype(jnp.float32)
        return (gf + (h.astype(jnp.float32) - gf)
                + lam * (b.astype(jnp.float32) - gf)).astype(g.dtype)
    return jax.tree.map(f, global_params, honest, backdoored)
