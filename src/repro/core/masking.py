"""Axis-mask trees: map FedFA width masks onto every parameter tensor.

For each parameter leaf we record which of its *trailing* axes carries
which width mask (``AX(row_mask, col_mask, ...)`` aligned to the last
``len(ms)`` axes, so depth-stacked leaves with a leading repeat axis R
broadcast automatically).  This single structure drives:

  * extraction / distribution (Alg. 3): ``apply_mask_tree``
  * gradient projection during local training
  * the per-element γ counts of the aggregation (Alg. 1 line 20)
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.masks import WidthMasks

Params = Dict[str, Any]


class AX:
    """Per-leaf axis masks aligned to the last len(ms) axes.

    Unregistered class => treated as a single leaf by jax.tree.map, which is
    exactly what we need when zipping against the params tree.
    """
    __slots__ = ("ms",)

    def __init__(self, *ms):
        self.ms = ms

    def __repr__(self):
        return f"AX({','.join('None' if m is None else str(m.shape) for m in self.ms)})"


def _rep(mask: Optional[jax.Array], k: int) -> Optional[jax.Array]:
    return None if mask is None else jnp.repeat(mask, k)


def _norm_ax(cfg: ArchConfig, dm) -> Dict[str, AX]:
    if cfg.norm == "layernorm":
        return {"scale": AX(dm), "bias": AX(dm)}
    return {"scale": AX(dm)}


def _attn_ax(cfg: ArchConfig, m: WidthMasks) -> Dict[str, AX]:
    hd = cfg.head_dim
    h = _rep(m.heads, hd)
    kv = _rep(m.kv_heads, hd)
    return {"wq": AX(m.d_model, h), "wk": AX(m.d_model, kv),
            "wv": AX(m.d_model, kv), "wo": AX(h, m.d_model)}


def _ffn_ax(cfg: ArchConfig, m: WidthMasks) -> Dict[str, AX]:
    if cfg.norm == "layernorm":
        return {"w_in": AX(m.d_model, m.d_ff), "b_in": AX(m.d_ff),
                "w_out": AX(m.d_ff, m.d_model), "b_out": AX(m.d_model)}
    return {"w_gate": AX(m.d_model, m.d_ff), "w_up": AX(m.d_model, m.d_ff),
            "w_down": AX(m.d_ff, m.d_model)}


def _moe_ax(cfg: ArchConfig, m: WidthMasks) -> Dict[str, AX]:
    p = {"router": AX(m.d_model, m.experts),
         "w_gate": AX(m.experts, m.d_model, None),
         "w_up": AX(m.experts, m.d_model, None),
         "w_down": AX(m.experts, None, m.d_model)}
    if cfg.moe.dense_residual:
        p["dense"] = {"w_gate": AX(m.d_model, m.d_ff),
                      "w_up": AX(m.d_model, m.d_ff),
                      "w_down": AX(m.d_ff, m.d_model)}
    return p


def _ssd_ax(cfg: ArchConfig, m: WidthMasks) -> Dict[str, AX]:
    s = cfg.ssm
    di = s.d_inner(cfg.d_model)
    N, nh, hp = s.d_state, s.n_heads(cfg.d_model), s.head_dim
    inner = _rep(m.ssm_heads, hp)
    ones_n = jnp.ones((N,), jnp.float32)
    if inner is None:
        proj_col = conv_col = None
    else:
        proj_col = jnp.concatenate([inner, inner, ones_n, ones_n, m.ssm_heads])
        conv_col = jnp.concatenate([inner, ones_n, ones_n])
    return {"in_proj": AX(m.d_model, proj_col),
            "conv_w": AX(None, conv_col), "conv_b": AX(conv_col),
            "A_log": AX(m.ssm_heads), "D": AX(m.ssm_heads),
            "dt_bias": AX(m.ssm_heads), "norm": AX(inner),
            "out_proj": AX(inner, m.d_model)}


def _rglru_ax(cfg: ArchConfig, m: WidthMasks) -> Dict[str, AX]:
    dr = m.d_rnn
    return {"in_x": AX(m.d_model, dr), "in_gate": AX(m.d_model, dr),
            "conv_w": AX(None, dr), "conv_b": AX(dr),
            "w_r": AX(dr, dr), "b_r": AX(dr), "w_i": AX(dr, dr),
            "b_i": AX(dr), "lam": AX(dr), "out": AX(dr, m.d_model)}


def _block_ax(kind: str, cfg: ArchConfig, m: WidthMasks, cross: bool) -> Dict[str, Any]:
    if kind == "attn":
        p = {"ln1": _norm_ax(cfg, m.d_model), "attn": _attn_ax(cfg, m),
             "ln2": _norm_ax(cfg, m.d_model),
             "ffn": _moe_ax(cfg, m) if cfg.moe else _ffn_ax(cfg, m)}
        if cross:
            p["lnx"] = _norm_ax(cfg, m.d_model)
            p["xattn"] = _attn_ax(cfg, m)
        return p
    if kind == "ssd":
        return {"ln": _norm_ax(cfg, m.d_model), "ssd": _ssd_ax(cfg, m)}
    if kind == "rglru":
        return {"ln1": _norm_ax(cfg, m.d_model), "rg": _rglru_ax(cfg, m),
                "ln2": _norm_ax(cfg, m.d_model), "ffn": _ffn_ax(cfg, m)}
    raise ValueError(kind)


def axis_mask_tree(cfg: ArchConfig, m: WidthMasks) -> Params:
    """Tree matching init_params structure; leaves are AX objects."""
    cross = cfg.encoder is not None
    t: Params = {"embed": AX(None, m.d_model)}
    stages = []
    for unit, reps in cfg.stages():
        stages.append(tuple(_block_ax(k, cfg, m, cross) for k in unit))
    t["stages"] = tuple(stages)
    t["final_norm"] = _norm_ax(cfg, m.d_model)
    if not cfg.tie_embeddings:
        t["lm_head"] = AX(m.d_model, None)
    if cfg.rope_theta <= 0.0:
        t["pos_embed"] = AX(None, m.d_model)
    if cfg.vision is not None:
        t["projector"] = {"w1": AX(None, m.d_model),
                          "w2": AX(m.d_model, m.d_model)}
    if cfg.encoder is not None:
        t["encoder"] = {"blocks": _block_ax("attn", cfg, m, cross=False),
                        "final_norm": _norm_ax(cfg, m.d_model)}
    return t


def _apply_ax(leaf: jax.Array, ax: AX) -> jax.Array:
    out = leaf
    n = len(ax.ms)
    for i, mv in enumerate(ax.ms):
        if mv is None:
            continue
        shape = [1] * out.ndim
        shape[out.ndim - n + i] = mv.shape[0]
        out = out * mv.reshape(shape).astype(out.dtype)
    return out


def apply_mask_tree(params: Params, axtree: Params) -> Params:
    """Extraction / distribution (Alg. 3 width step): zero masked channels."""
    return jax.tree.map(_apply_ax, params, axtree,
                        is_leaf=lambda x: isinstance(x, AX))


def mask_density(leaf_shape: Tuple[int, ...], ax: AX):
    """Fraction + per-element mask broadcast product for γ accounting."""
    out = jnp.ones((), jnp.float32)
    n = len(ax.ms)
    for i, mv in enumerate(ax.ms):
        if mv is None:
            continue
        shape = [1] * len(leaf_shape)
        shape[len(leaf_shape) - n + i] = mv.shape[0]
        out = out * mv.reshape(shape)
    return out


def active_fraction(ax: AX) -> jax.Array:
    """Product of per-axis active fractions (scalar, traced-safe)."""
    f = jnp.ones((), jnp.float32)
    for mv in ax.ms:
        if mv is not None:
            f = f * jnp.mean(mv)
    return f


def mask_gradients(grads: Params, axtree: Params) -> Params:
    """Project gradients back onto the client's subspace (defensive; the
    masked forward already yields zero grads outside it)."""
    return apply_mask_tree(grads, axtree)
