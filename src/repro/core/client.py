"""Client-side local update (Alg. 1 line 9, LocalUpdate).

Runs E local steps of SGD+momentum (paper Table 6) on the client's masked
sub-model.  Gradients are projected back onto the client subspace after
each step (defensive — masked forwards already produce zero grads outside
it) so padded-dense simulation stays exactly on the small-model manifold.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.masking import apply_mask_tree, axis_mask_tree
from repro.models import model as model_mod
from repro.models.masks import WidthMasks
from repro.optim import init_opt, opt_update

Params = Dict[str, Any]


def local_update(global_params: Params, cfg: ArchConfig, batches, *,
                 masks: WidthMasks, gates: jax.Array,
                 lr: float, task: str = "lm",
                 class_mask: Optional[jax.Array] = None,
                 optimizer: Optional[str] = None,
                 momentum: float = 0.9,
                 weight_decay: float = 1e-4) -> Tuple[Params, jax.Array]:
    """batches: pytree with leading step axis, e.g. {'tokens': (E, B, S)}.
    Returns ``(params, losses)``: the client's updated (masked) model and
    the (E,) per-step training losses."""
    ax = axis_mask_tree(cfg, masks)
    params = apply_mask_tree(global_params, ax)        # Alg. 3: distribution
    opt_name = optimizer or cfg.optimizer
    opt = init_opt(params, opt_name)

    def step(carry, batch):
        p, st = carry
        (_, _metrics), grads = jax.value_and_grad(
            model_mod.loss_fn, has_aux=True)(
                p, cfg, batch, masks=masks, gates=gates, task=task,
                class_mask=class_mask)
        grads = apply_mask_tree(grads, ax)
        p, st = opt_update(opt_name, p, grads, st, lr,
                           **({"momentum": momentum, "weight_decay": weight_decay}
                              if opt_name == "sgd" else {}))
        p = apply_mask_tree(p, ax)                     # weight decay drift guard
        return (p, st), _metrics["loss"]

    (params, _), losses = jax.lax.scan(step, (params, opt), batches)
    return params, losses
