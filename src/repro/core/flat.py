"""Flat-buffer aggregation engine: Alg. 1 on one contiguous (m, N) buffer.

The tree engine in ``repro.core.fedfa`` runs Alg. 1 as per-leaf tree-maps
inside a ``lax.scan`` over clients — O(leaves x clients) tiny dispatches and
a serial reduction.  This module packs the parameter pytree into a single
contiguous f32 buffer per client (``FlatIndex`` records the static layout:
leaf offsets/shapes/dtypes, per-row segment ids, depth-stage info and graft
gather maps) and reimplements the algorithm as a handful of segment-wise
passes over the flat cohort buffer:

  * graft (Alg. 2)          — one flat gather per client,
  * trimmed norms (§4.3)    — per-(client, segment) quantile threshold AND
                              trimmed sum-of-squares fused into ONE pass
                              over each cohort row via the Pallas
                              ``fedfa_quantile`` kernel on TPU (jnp top_k
                              tail path on CPU),
  * (M', γ) accumulation    — two fused weighted reductions over the client
                              axis via the Pallas ``scaled_accum`` kernel on
                              TPU (pure-jnp ``ref`` fallback on CPU).

Per-client weights that vary only per (leaf, row) — depth gates, data
counts, scaling factors α — live in small (m, n_segments) tables gathered
onto the buffer through ``row_of``, so the elementwise work is a single
fused pass regardless of how many leaves the model has.
"""
from __future__ import annotations

import functools
from collections import OrderedDict
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.tree_util import tree_flatten_with_path

from repro.configs.base import ArchConfig
# one classification rule shared with the tree engine (fedfa imports this
# module only lazily, so no cycle)
from repro.core.fedfa import _path_stage_info
from repro.core.masking import (AX, active_fraction, axis_mask_tree,
                                mask_density)
from repro.kernels.fedfa_agg import ops as agg_ops
from repro.kernels.fedfa_quantile import multilevel as quant_ml
from repro.kernels.fedfa_quantile import ops as quant_ops
from repro.models.masks import WidthMasks

Params = Dict[str, Any]
_IS_AX = lambda x: isinstance(x, AX)


@dataclass(frozen=True)
class LeafSpec:
    path: Tuple
    shape: Tuple[int, ...]
    dtype: Any
    offset: int
    size: int
    stacked: bool            # has a leading repeat axis
    stage: Optional[int]     # stage index for "stages" leaves, else None
    lead: int                # rows R (1 for unstacked leaves)
    rest: int                # elements per row
    seg0: int                # first global segment id of this leaf


class FlatIndex:
    """Static flat layout of a parameter pytree (host-side numpy).

    Segments are (leaf, row) pairs: one per repeat of a depth-stacked leaf,
    one per unstacked leaf — exactly the granularity at which trimmed norms,
    scaling factors and depth gates vary.

    ``pad_to`` rounds the flat length up to a multiple of the mesh
    model-shard count (``n_padded``) so the (N,) axis divides evenly when
    sharded over ``model`` — mirroring the inert ``n_data = 0`` client rows
    of ``repro.sharding.cohort``.  The tail ``[n, n_padded)`` is an inert
    zero segment: buffers are zero there, the width-mask density is zero
    (so contrib/counts vanish and the γ = 0 rule keeps the merged global at
    zero), the graft map is the identity, and no ``LeafSpec`` covers it, so
    trimmed norms and α never see it.  All leaf offsets stay static and
    independent of the padding.
    """

    def __init__(self, params: Params, pad_to: int = 1):
        leaves, self.treedef = tree_flatten_with_path(params)
        specs, row_of, seg_row, seg_stage0 = [], [], [], []
        g_base, g_row, g_rest = [], [], []
        off = seg = 0
        for path, x in leaves:
            stacked, stage = _path_stage_info(path)
            shape = tuple(x.shape)
            size = int(np.prod(shape, dtype=np.int64)) if shape else 1
            lead = shape[0] if stacked else 1
            rest = size // lead
            specs.append(LeafSpec(path, shape, jnp.result_type(x), off, size,
                                  stacked, stage, lead, rest, seg))
            row_of.append(np.repeat(
                np.arange(seg, seg + lead, dtype=np.int32), rest))
            seg_row.extend(range(lead))
            seg_stage0.extend([stacked and stage == 0] * lead)
            rel = np.arange(size, dtype=np.int64)
            if stacked and stage == 0:       # graft gathers along the rows
                g_base.append(off + rel % rest)
                g_row.append((rel // rest).astype(np.int32))
                g_rest.append(np.full(size, rest, np.int32))
            else:                            # identity (g_rest = 0)
                g_base.append(off + rel)
                g_row.append(np.zeros(size, np.int32))
                g_rest.append(np.zeros(size, np.int32))
            off += size
            seg += lead
        self.leaves = tuple(specs)
        self.n = off
        self.n_segments = seg
        pad = (-off) % max(int(pad_to), 1)
        self.n_padded = off + pad
        if pad:                      # inert tail: density 0, identity graft
            row_of.append(np.zeros(pad, np.int32))
            rel = off + np.arange(pad, dtype=np.int64)
            g_base.append(rel)
            g_row.append(np.zeros(pad, np.int32))
            g_rest.append(np.zeros(pad, np.int32))
        self.row_of = np.concatenate(row_of)
        self.seg_row = np.asarray(seg_row, np.int32)
        self.seg_stage0 = np.asarray(seg_stage0)
        self.g_base = np.concatenate(g_base).astype(np.int32)
        self.g_row = np.concatenate(g_row)
        self.g_rest = np.concatenate(g_rest)


def _segment_maps(index: FlatIndex):
    """Static per-position segment map for the two-stage distributed
    quantile: (seg_id, seg_len, leaf_of_seg) numpy arrays, memoized on the
    index.  ``seg_id`` (n_padded,) is ``row_of`` with the inert pad tail
    remapped to -1 (``row_of`` stores 0 there so weight gathers stay
    in-bounds, but the quantile kernel must EXCLUDE pads, not bin them into
    segment 0); ``seg_len`` (S,) is the global element count per segment and
    ``leaf_of_seg`` (S,) maps each segment to its leaf (for per-leaf active
    fractions)."""
    maps = getattr(index, "_segment_maps", None)
    if maps is None:
        seg_id = index.row_of.astype(np.int32).copy()
        seg_id[index.n:] = -1
        seg_len = np.zeros(index.n_segments, np.int32)
        leaf_of = np.zeros(index.n_segments, np.int32)
        for li, spec in enumerate(index.leaves):
            seg_len[spec.seg0:spec.seg0 + spec.lead] = spec.rest
            leaf_of[spec.seg0:spec.seg0 + spec.lead] = li
        maps = (seg_id, seg_len, leaf_of)
        index._segment_maps = maps
    return maps


_INDEX_CACHE: "OrderedDict[Any, FlatIndex]" = OrderedDict()
_INDEX_CACHE_MAX = 64


def get_index(params: Params, pad_to: int = 1) -> FlatIndex:
    """Build (or fetch the cached) FlatIndex for this params structure.

    Keyed on the treedef *and* the leaf (shape, dtype) layout: two pytrees
    with different container structure can share the same flatten order (e.g.
    a tuple vs a list at the same path), and unflatten must restore the right
    one.  ``pad_to`` (the mesh model-shard count, see ``FlatIndex``)
    participates in the key — the same tree padded for different meshes has
    different buffer widths.  LRU-bounded so long-lived processes over many
    model configs don't grow the cache without limit.
    """
    leaves, treedef = tree_flatten_with_path(params)
    key = (treedef, int(pad_to),
           tuple((tuple(x.shape), jnp.result_type(x).name) for _, x in leaves))
    idx = _INDEX_CACHE.get(key)
    if idx is None:
        idx = _INDEX_CACHE[key] = FlatIndex(params, pad_to=pad_to)
        while len(_INDEX_CACHE) > _INDEX_CACHE_MAX:
            _INDEX_CACHE.popitem(last=False)
    else:
        _INDEX_CACHE.move_to_end(key)
    return idx


def _check_layout(index: FlatIndex, leaves, stacked: bool) -> None:
    """Trace-time guard: the tree being packed must have the leaf layout the
    index was built from (jax.tree.leaves order == tree_flatten_with_path
    order), else offsets would silently misalign."""
    drop = 1 if stacked else 0
    if len(leaves) != len(index.leaves) or any(
            tuple(x.shape[drop:]) != s.shape
            for x, s in zip(leaves, index.leaves)):
        raise ValueError("tree structure does not match FlatIndex layout")


def flatten(index: FlatIndex, tree: Params) -> jax.Array:
    """Pack one pytree into a contiguous (n_padded,) f32 buffer (the inert
    tail, if any, is zeros)."""
    leaves = jax.tree.leaves(tree)
    _check_layout(index, leaves, stacked=False)
    parts = [jnp.ravel(x).astype(jnp.float32) for x in leaves]
    if index.n_padded > index.n:
        parts.append(jnp.zeros((index.n_padded - index.n,), jnp.float32))
    return jnp.concatenate(parts)


def flatten_stacked(index: FlatIndex, tree: Params) -> jax.Array:
    """Pack a client-stacked pytree (leading axis m) into (m, n_padded) f32
    (zero inert tail)."""
    leaves = jax.tree.leaves(tree)
    _check_layout(index, leaves, stacked=True)
    m = leaves[0].shape[0]
    parts = [x.reshape(m, -1).astype(jnp.float32) for x in leaves]
    if index.n_padded > index.n:
        parts.append(jnp.zeros((m, index.n_padded - index.n), jnp.float32))
    return jnp.concatenate(parts, axis=1)


def unflatten(index: FlatIndex, buf: jax.Array) -> Params:
    """Unpack a (n_padded,) buffer back into the pytree (original leaf
    dtypes); the inert tail is dropped."""
    outs = [buf[s.offset:s.offset + s.size].reshape(s.shape).astype(s.dtype)
            for s in index.leaves]
    return jax.tree_util.tree_unflatten(index.treedef, outs)


def _density_and_fraction(cfg: ArchConfig, index: FlatIndex, mk: WidthMasks):
    """One client's flat 0/1 width-mask density (n_padded,) and per-leaf
    active fraction (n_leaves,).  The inert tail has density 0, which keeps
    the pad region out of both (M', γ) sums."""
    ax = axis_mask_tree(cfg, mk)
    by_path = dict(tree_flatten_with_path(ax, is_leaf=_IS_AX)[0])
    dens, fracs = [], []
    for spec in index.leaves:
        axl = by_path[spec.path]
        d = jnp.broadcast_to(mask_density(spec.shape, axl), spec.shape)
        dens.append(jnp.ravel(d).astype(jnp.float32))
        fracs.append(active_fraction(axl))
    if index.n_padded > index.n:
        dens.append(jnp.zeros((index.n_padded - index.n,), jnp.float32))
    return jnp.concatenate(dens), jnp.stack(fracs)


def _graft_flat(index: FlatIndex, buf: jax.Array, gmap: jax.Array) -> jax.Array:
    """Alg. 2 on the flat buffer: one gather (identity off stage 0)."""
    src = jnp.asarray(index.g_base) \
        + jnp.take(gmap, jnp.asarray(index.g_row), mode="clip") \
        * jnp.asarray(index.g_rest)
    return jnp.take(buf, src, mode="clip")


# ---------------------------------------------------------------------------
# Quantized admission: per-(client, segment) symmetric scales
# ---------------------------------------------------------------------------

UPDATE_DTYPES = ("f32", "bf16", "int8")


def update_dtype_of(name: str):
    """jnp dtype for an ``--update-dtype`` name (the cohort admission tier)."""
    if name not in UPDATE_DTYPES:
        raise ValueError(f"update_dtype must be one of {UPDATE_DTYPES}, "
                         f"got {name!r}")
    return {"f32": jnp.float32, "bf16": jnp.bfloat16,
            "int8": jnp.int8}[name]


def _quant_maps(index: FlatIndex):
    """Static column -> scale-slot map for quantized admission, memoized on
    the index.  ``col_of`` (n_padded,) int32 sends each buffer position to
    its segment's scale column, with the inert pad tail sent to the extra
    slot S — that slot always carries scale 0, so the int8 roundtrip cannot
    inject nonzero bits into the N-pad."""
    maps = getattr(index, "_quant_maps", None)
    if maps is None:
        seg_id, _, _ = _segment_maps(index)
        col_of = seg_id.astype(np.int32).copy()
        col_of[col_of < 0] = index.n_segments
        maps = (col_of,)
        index._quant_maps = maps
    return maps


def quantize_cohort(index: FlatIndex, x: jax.Array,
                    update_dtype: str) -> Tuple[jax.Array, jax.Array]:
    """Quantize a grafted, density-masked (m, n_padded) f32 cohort to the
    admission dtype.  Returns (x_q, scales (m, S) f32).

    int8: symmetric per-(client, segment) scales — scale = max|x|/127 over
    the segment, computed by one scatter-max into an (m, S+1) table (slot S
    collects the inert pad tail and is dropped).  All-zero segments keep
    scale 0, so both quantize and dequantize map them to exact zeros.
    bf16: a plain downcast; scales are all-ones so the fused consumers
    treat both tiers uniformly.  f32 passes through (identity scales).
    """
    m = x.shape[0]
    S = index.n_segments
    if update_dtype == "f32":
        return x, jnp.ones((m, S), jnp.float32)
    if update_dtype == "bf16":
        return x.astype(jnp.bfloat16), jnp.ones((m, S), jnp.float32)
    (col_of,) = _quant_maps(index)
    col = jnp.asarray(col_of)
    seg_max = jnp.zeros((m, S + 1), jnp.float32).at[:, col].max(jnp.abs(x))
    scales = seg_max[:, :S] / 127.0
    safe = jnp.where(seg_max > 0, seg_max / 127.0, 1.0)       # (m, S+1)
    q = jnp.clip(jnp.round(x / jnp.take(safe, col, axis=1)), -127.0, 127.0)
    # belt and braces on the inert tail: its scale slot is 0 (so dequant is
    # zero regardless), but keep the stored bits zero too
    q = jnp.where(jnp.asarray(col_of == S)[None, :], 0.0, q)
    return q.astype(jnp.int8), scales


def dequantize_cohort(index: FlatIndex, x_q: jax.Array,
                      scales: jax.Array) -> jax.Array:
    """f32 (m, n_padded) view of a quantized cohort: x_q · scale[col].  The
    inert pad tail reads the implicit scale-0 slot, so it dequantizes to
    exact zeros.  bf16 cohorts carry all-ones scales (plain upcast).  Used
    by error feedback, oracles and jnp fallbacks — the hot aggregation path
    never materializes this (m, N) product; dequantization is fused into
    the kernels via per-segment scale tables."""
    (col_of,) = _quant_maps(index)
    m = x_q.shape[0]
    full = jnp.concatenate(
        [scales.astype(jnp.float32), jnp.zeros((m, 1), jnp.float32)], axis=1)
    return x_q.astype(jnp.float32) * jnp.take(full, jnp.asarray(col_of),
                                              axis=1)


def _row_quantile(rows_abs: jax.Array, q: jax.Array, trim: float) -> jax.Array:
    """Per-row ``jnp.quantile(rows_abs, q, axis=-1)`` with per-client q,
    computed exactly from the top-(1-trim) tail via ``lax.top_k`` — the only
    part of the sorted order the threshold can touch, since q >= trim.
    O(L log k) instead of a full O(L log L) sort.  rows_abs (m, R, L),
    q (m,) -> (m, R)."""
    m, R, L = rows_abs.shape
    k = min(L, int(np.ceil((1.0 - trim) * (L - 1))) + 2)
    top = jax.lax.top_k(rows_abs, k)[0]            # (m, R, k) descending
    p = q * (L - 1)                                # fractional sort position
    i0 = jnp.floor(p)
    frac = (p - i0).astype(rows_abs.dtype)
    d0 = (L - 1) - i0.astype(jnp.int32)            # descending index of floor
    d1 = jnp.maximum(d0 - 1, 0)                    # descending index of ceil
    take = lambda d: jnp.take_along_axis(
        top, jnp.broadcast_to(d[:, None, None], (m, R, 1)), axis=-1,
        mode="clip")[..., 0]
    v0, v1 = take(d0), take(d1)
    return v0 + (v1 - v0) * frac[:, None]


def _rows_trimmed_sq(rows: jax.Array, t: jax.Array) -> jax.Array:
    """Σ w²·[|w|<=t] over the last axis. rows (m, R, L), t (m, R) -> (m, R).
    Companion of the jnp top_k path; the kernel path fuses this reduction
    into the quantile pass itself (``_rows_trimmed_stats``)."""
    return jnp.sum(jnp.where(jnp.abs(rows) <= t[..., None], rows * rows, 0.0),
                   axis=-1)


def _rows_trimmed_stats(rows: jax.Array, q: jax.Array, trim: float,
                        use_kernel: bool, interpret: bool,
                        scale: Optional[jax.Array] = None) -> Tuple:
    """Per-row (quantile threshold, trimmed Σw²) for SIGNED rows (m, R, L)
    with per-client q (m,) -> ((m, R), (m, R)).

    Kernel path (``use_kernel``/``interpret``): the fused Pallas
    ``fedfa_quantile`` kernel — threshold by bit-pattern count-and-partition
    plus the trimmed reduction in one read of each row.  jnp path: exact
    top-(1-trim) tail quantile (``_row_quantile``) then a masked reduction —
    separate passes over the data.

    ``scale`` (m, R) dequantizes quantized rows on the fly: the kernel path
    forwards it as a per-row constant (the rows stay in their admitted
    dtype, read once); the jnp path materializes the f32 product first.
    """
    m, R, L = rows.shape
    if use_kernel or interpret:
        t, sq = quant_ops.row_trimmed_stats(
            rows.reshape(m * R, L), jnp.repeat(q, R),
            scale=None if scale is None else scale.reshape(m * R),
            use_kernel=use_kernel, interpret=interpret)
        return t.reshape(m, R), sq.reshape(m, R)
    rows_f = rows.astype(jnp.float32)
    if scale is not None:
        rows_f = rows_f * scale[..., None].astype(jnp.float32)
    rows_abs = jnp.abs(rows_f)
    t = _row_quantile(rows_abs, q, trim)
    return t, _rows_trimmed_sq(rows_abs, t)


def _cohort_norms(index: FlatIndex, xm: jax.Array, fracs: jax.Array,
                  trim: float, use_kernel: bool, interpret: bool,
                  mesh=None, scales: Optional[jax.Array] = None) -> jax.Array:
    """Per-(client, segment) trimmed norms: (m, N) masked updates +
    (m, n_leaves) active fractions -> (m, S).

    Every op here — per-leaf slicing along N, |.|, the quantile threshold,
    the trimmed sum of squares — is independent per client, so under a mesh
    the whole pass runs inside ``shard_map`` on each device's client shard
    (the fused quantile kernel is per-row and adds no collective).  Left to
    sharding propagation, XLA's top_k partitioning instead all-gathers the
    client axis leaf by leaf, which re-materializes the cohort buffer on
    every device.

    With real model shards (and the kernel path selected) the pass is 2-D:
    each device runs the segmented two-stage quantile on its
    (m/D, N/n_model) slice of the P("data", "model") buffer and the only
    cross-shard traffic is the psum of per-level histogram planes over
    ``model`` (``kernels.fedfa_quantile.multilevel``) — the model-replicated
    (m/D, N) transient is gone.  Requires the index padded with
    ``sharding.cohort.pad_unit`` so the local slice tiles the kernel evenly;
    otherwise the pass falls back to the model-replicated layout.

    ``scales`` (m, S) declares ``xm`` quantized (int8/bf16): per-segment
    dequant scales ride into the quantile kernels as per-row / per-segment
    constants — the rows are never re-materialized as f32.
    """

    def norms_local(xm_l, fracs_l, *rest):
        sc_l = rest[0] if rest else None
        m_l = xm_l.shape[0]
        cols = []
        for li, spec in enumerate(index.leaves):
            rows = xm_l[:, spec.offset:spec.offset + spec.size] \
                .reshape(m_l, spec.lead, spec.rest)
            # shifted quantile: the trim-quantile of active magnitudes equals
            # the 1-(1-trim)·f quantile of the zero-padded row
            q = 1.0 - (1.0 - trim) * fracs_l[:, li]
            sc = None if sc_l is None else sc_l[:, spec.seg0:spec.seg0
                                                + spec.lead]
            _, sq = _rows_trimmed_stats(rows, q, trim, use_kernel, interpret,
                                        scale=sc)
            cols.append(jnp.sqrt(sq))
        return jnp.concatenate(cols, axis=1)

    from repro.sharding import cohort as csh
    extra = () if scales is None else (scales,)
    if not csh.shardable(mesh, xm.shape[0]):
        return norms_local(xm, fracs, *extra)
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    ms = csh.model_shards(mesh)
    extra_spec = () if scales is None else (P("data", None),)
    if (ms > 1 and (use_kernel or interpret)
            and xm.shape[1] % (ms * quant_ml.TILE) == 0):
        seg_id, seg_len, leaf_of = _segment_maps(index)

        def norms_2d(xm_l, fracs_l, seg_l, *rest):
            q_seg = 1.0 - (1.0 - trim) * fracs_l[:, jnp.asarray(leaf_of)]
            _, sq = quant_ml.segmented_trimmed_stats(
                xm_l, seg_l[0], jnp.asarray(seg_len), q_seg,
                scales=rest[0] if rest else None,
                axis_name=csh.MODEL_AXIS,
                interpret=interpret or jax.default_backend() != "tpu")
            return jnp.sqrt(sq)

        # seg_id enters as a host constant (constvar, not a broadcast eqn)
        # so the traced program's only row-sized read is the kernel itself
        return shard_map(
            norms_2d, mesh=mesh,
            in_specs=(P("data", "model"), P("data", None),
                      P(None, "model")) + extra_spec,
            out_specs=P("data", None), check_rep=False)(
                xm, fracs, np.asarray(seg_id)[None, :], *extra)
    return shard_map(norms_local, mesh=mesh,
                     in_specs=(P("data", None), P("data", None)) + extra_spec,
                     out_specs=P("data", None), check_rep=False)(
                         xm, fracs, *extra)


def aggregate_buffers(index: FlatIndex, g_flat: jax.Array, x: jax.Array,
                      cfg: ArchConfig, masks: WidthMasks, gates: jax.Array,
                      gmaps: jax.Array, n_data: jax.Array, *,
                      graft: bool = True, pregrafted: bool = False,
                      scale: bool = True, scales: Optional[jax.Array] = None,
                      trim: float = 0.95, eps: float = 1e-12,
                      use_kernel: Optional[bool] = None,
                      interpret: bool = False, mesh=None) -> jax.Array:
    """Alg. 1 entirely in flat space: (N,) global + (m, N) cohort buffers in,
    (N,) new global out — no pytree packing/unpacking, so the resident
    multi-round driver (``repro.core.round``) can keep both buffers donated
    across rounds.  ``aggregate_flat`` below is the tree-in/tree-out wrapper.

    With ``mesh`` set, the client axis m is laid out over the mesh ``data``
    axis (``repro.sharding.cohort``).  With real model shards and the
    kernel path, the N axis splits EARLY: densities, the distributed
    two-stage trimmed-norm pass (histogram psums over ``model``, see
    ``_cohort_norms``) and both fused (M', γ) reductions consume
    P("data", "model") slices directly — per-shard partial sums finished
    by one N/n_model psum over ``data``, no reduce-scatter, so M', Γ, and
    the merged global below live as N/n_model slices per device — zero
    all-gathers in the lowering, with ``g_flat`` consumed shard-locally by
    the γ = 0 merge.  Only the graft gather (a data-dependent cross-shard
    row permutation) still opens a transient model-replicated window;
    ``pregrafted=True`` declares the rows were grafted upstream (the async
    admit does this), keeping graft-on weighting semantics while skipping
    the gather — the program is then 2-D end-to-end.  Cohorts padded
    with ``n_data = 0`` rows aggregate identically to the unpadded cohort:
    zero weight in both sums, and excluded from the α mean below.  The
    parameter axis's inert zero tail (``index.n_padded``, see ``FlatIndex``)
    is likewise invisible: density 0 in both sums and outside every norm
    segment.

    ``scales`` (m, S) switches the cohort to QUANTIZED admission: ``x`` is
    int8/bf16, already grafted AND density-masked (``quantize_cohort``
    quantizes x·dens, so the 0/1 width mask is baked into the stored
    values).  Dequantization is fused into every consumer — the trimmed
    norms read the rows through per-segment scale constants, and the (M')
    reduction folds scale·α·gate into the per-(client, segment) weight
    table of ``agg_ops.accumulate_quant`` — so no f32 (m, N) dequantized
    transient ever exists.  The γ counts side is mask data, identical to
    the f32 path.
    """
    from repro.sharding import cohort as csh
    if scales is not None and graft and not pregrafted:
        raise ValueError("quantized cohorts must be grafted before "
                         "quantization (pass pregrafted=True)")
    if use_kernel is None:
        use_kernel = jax.default_backend() == "tpu"
    ms = csh.model_shards(mesh)
    two_d = (ms > 1 and csh.shardable(mesh, x.shape[0])
             and (use_kernel or interpret)
             and index.n_padded % (ms * quant_ml.TILE) == 0)
    constrain = ((lambda a: csh.constrain_cohort_buffer(a, mesh)) if two_d
                 else (lambda a: csh.constrain_cohort(a, mesh)))

    dens_fn = jax.vmap(functools.partial(_density_and_fraction, cfg, index))
    if two_d:
        # build each device's (m/D, N/n_model) density slice SHARD-LOCALLY:
        # left to propagation, GSPMD reshards the per-leaf concatenate onto
        # the model axis with a zero-pad + row-width all-reduce — exactly
        # the model-replicated (m/D, N) transient this path retires
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P

        def _dens_local(mk):
            d, f = dens_fn(mk)
            cols = index.n_padded // ms
            k = jax.lax.axis_index(csh.MODEL_AXIS)
            return jax.lax.dynamic_slice_in_dim(d, k * cols, cols, axis=1), f

        dens, fracs = shard_map(
            _dens_local, mesh=mesh,
            in_specs=(jax.tree.map(lambda _: P(csh.DATA_AXIS), masks),),
            out_specs=(P(csh.DATA_AXIS, csh.MODEL_AXIS),
                       P(csh.DATA_AXIS, None)),
            check_rep=False)(masks)
    else:
        dens, fracs = dens_fn(masks)
        dens = constrain(dens)
    x_g = x
    if graft and not pregrafted:
        x_g = jax.vmap(functools.partial(_graft_flat, index))(
            csh.constrain_cohort(x, mesh), gmaps)
    x_g = constrain(x_g)

    if graft:
        dwrow = None   # grafting weights every depth slot equally (1.0)
    else:  # depth gates weight stage-0 rows; everything else weight 1
        dwrow = jnp.where(jnp.asarray(index.seg_stage0)[None, :],
                          jnp.take(gates, jnp.asarray(index.seg_row), axis=1,
                                   mode="clip"),
                          1.0)

    alpha = None
    if scale:
        # quantized rows arrive density-masked, so the mask multiply (an
        # f32 (m, N) transient) only exists on the f32 path
        xm = x_g if scales is not None else x_g * dens
        norms = _cohort_norms(index, xm, fracs, trim, use_kernel, interpret,
                              mesh, scales=scales)                  # (m, S)
        # cross-client mean weighted by row validity: pad rows (n_data = 0)
        # must not shift α; with every row valid this is exactly the mean
        valid = (n_data > 0).astype(jnp.float32)                    # (m,)
        mean_norms = jnp.sum(valid[:, None] * norms, axis=0, keepdims=True) \
            / jnp.maximum(jnp.sum(valid), 1.0)
        alpha = mean_norms / jnp.maximum(norms, eps)

    row_of = jnp.asarray(index.row_of)
    gather = lambda w: jnp.take(w, row_of, axis=1, mode="clip")     # (m, N)
    if alpha is None:
        warow = dwrow
    else:
        warow = alpha if dwrow is None else dwrow * alpha
    ones_n = jnp.ones((index.n_padded,), jnp.float32)
    if scales is not None:
        # fused dequantize-accumulate: scale·gate·α collapse into one
        # (m, S) weight table gathered per column INSIDE the kernel — the
        # quantized rows are read exactly once, with no (m, N) f32 product
        seg_id, _, _ = _segment_maps(index)
        coeff = scales if warow is None else warow * scales
        Mp = agg_ops.accumulate_quant(
            x_g, n_data, coeff, jnp.asarray(seg_id), ones_n,
            use_kernel=use_kernel, interpret=interpret, mesh=mesh,
            cohort_2d=two_d)
    else:
        contrib = constrain(
            x_g * dens if warow is None else x_g * dens * gather(warow))
        Mp = agg_ops.accumulate(contrib, n_data, ones_n,
                                use_kernel=use_kernel, interpret=interpret,
                                mesh=mesh, cohort_2d=two_d)
    counts = constrain(
        dens if dwrow is None else dens * gather(dwrow))
    Gm = agg_ops.accumulate(counts, n_data, ones_n, use_kernel=use_kernel,
                            interpret=interpret, mesh=mesh, cohort_2d=two_d)

    upd = Mp / jnp.maximum(Gm, eps)
    return jnp.where(Gm > 0, upd, g_flat)  # γ = 0 keeps the global value


def aggregate_flat(global_params: Params, stacked_params: Params,
                   cfg: ArchConfig, masks: WidthMasks, gates: jax.Array,
                   gmaps: jax.Array, n_data: jax.Array, *, graft: bool = True,
                   scale: bool = True, trim: float = 0.95, eps: float = 1e-12,
                   use_kernel: Optional[bool] = None,
                   interpret: bool = False) -> Params:
    """Alg. 1 on the flat cohort buffer; numerically matches the tree engine
    (``fedfa.aggregate``) within float tolerance for every strategy preset."""
    index = get_index(global_params)
    g_flat = flatten(index, global_params)                          # (N,)
    x = flatten_stacked(index, stacked_params)                      # (m, N)
    out = aggregate_buffers(index, g_flat, x, cfg, masks, gates, gmaps,
                            n_data, graft=graft, scale=scale, trim=trim,
                            eps=eps, use_kernel=use_kernel,
                            interpret=interpret)
    return unflatten(index, out)
