"""Client-side architecture selection: ZiCo-style zero-shot NAS
(Li et al., arXiv:2301.11300 — paper §5.1) + a small evolutionary search.

ZiCo proxy: sum over layers of log(E|g| / std|g|) where the statistics of
per-parameter absolute gradients are taken across a few minibatches —
higher inverse coefficient of variation correlates with trainability.
Only forward+backward passes are needed (cost-effective, per the paper).
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass
from typing import Callable, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.masking import apply_mask_tree, axis_mask_tree
from repro.models import model as model_mod
from repro.models.masks import ClientArch, max_section_depths


def zico_score(cfg: ArchConfig, arch: ClientArch, params, batches,
               task: str = "lm") -> float:
    """batches: pytree with leading axis = number of probe minibatches."""
    masks = arch.masks(cfg)
    gates = arch.gates(cfg)
    ax = axis_mask_tree(cfg, masks)
    p = apply_mask_tree(params, ax)

    def gradfn(batch):
        g = jax.grad(lambda pp: model_mod.loss_fn(
            pp, cfg, batch, masks=masks, gates=gates, task=task)[0])(p)
        return apply_mask_tree(g, ax)

    grads = jax.lax.map(gradfn, batches)               # leading axis = probes
    score = jnp.zeros((), jnp.float32)
    for g in jax.tree.leaves(grads):
        ga = jnp.abs(g.astype(jnp.float32))
        mean = jnp.mean(ga, axis=0)                    # across probe batches
        std = jnp.std(ga, axis=0) + 1e-9
        # only count active entries (mean>0 under masks)
        ratio = jnp.where(mean > 0, mean / std, 0.0)
        denom = jnp.maximum(jnp.sum(mean > 0), 1)
        score = score + jnp.log(jnp.sum(ratio) / denom + 1e-9)
    return float(score)


@dataclass
class SearchSpace:
    width_mults: Tuple[float, ...] = (0.25, 0.5, 0.75, 1.0)
    # per-section depth choices are 1..max implicitly


def random_arch(cfg: ArchConfig, space: SearchSpace, rng: np.random.Generator) -> ClientArch:
    maxd = max_section_depths(cfg)
    w = float(rng.choice(space.width_mults))
    d = tuple(int(rng.integers(1, m + 1)) for m in maxd)
    return ClientArch(w, d)


def mutate(cfg: ArchConfig, arch: ClientArch, space: SearchSpace,
           rng: np.random.Generator) -> ClientArch:
    maxd = max_section_depths(cfg)
    w = arch.width_mult
    d = list(arch.section_depths)
    if rng.random() < 0.5:
        ws = list(space.width_mults)
        i = ws.index(min(ws, key=lambda v: abs(v - w)))
        i = int(np.clip(i + rng.choice([-1, 1]), 0, len(ws) - 1))
        w = ws[i]
    else:
        s = int(rng.integers(len(d)))
        d[s] = int(np.clip(d[s] + rng.choice([-1, 1]), 1, maxd[s]))
    return ClientArch(float(w), tuple(d))


def evolutionary_search(cfg: ArchConfig, params, batches, *,
                        task: str = "lm", space: SearchSpace = SearchSpace(),
                        population: int = 8, generations: int = 3,
                        seed: int = 0) -> ClientArch:
    """ZiCo-guided evolutionary search (paper §5.1: clients pick local
    architectures with ZiCo over the candidate grid of Table 5)."""
    rng = np.random.default_rng(seed)
    pop = [random_arch(cfg, space, rng) for _ in range(population)]
    scored = [(zico_score(cfg, a, params, batches, task), a) for a in pop]
    for _ in range(generations):
        scored.sort(key=lambda t: -t[0])
        parents = [a for _, a in scored[: max(2, population // 2)]]
        children = [mutate(cfg, parents[int(rng.integers(len(parents)))], space, rng)
                    for _ in range(population - len(parents))]
        scored = scored[: len(parents)] + [
            (zico_score(cfg, a, params, batches, task), a) for a in children]
    scored.sort(key=lambda t: -t[0])
    return scored[0][1]
