"""Continuous-arrival async round engine (FedBuff-style, bounded staleness).

The resident driver (``repro.core.round``) is strictly synchronous: one
straggler stalls the whole cohort.  This module runs the same donated
buffers as a fixed-capacity **slot pool**: client updates are admitted into
rows of the resident (rows, N) cohort buffer as they land in simulated
time, and a **merge** folds the arrived rows into the (N,) global whenever
``merge_k`` rows are ready OR a deadline fires.  Staleness is bounded and
discounted: a row dispatched at global version v and merged at version v'
carries weight ``n_data * staleness_weight(v' - v)``, zero beyond
``staleness_max`` — folded into the existing validity-weighted ``nd`` path
of ``flat.aggregate_buffers``, so the fused grafting/trimmed-quantile
kernels are reused unchanged (a zero-weight row is inert in every
reduction, exactly like a mesh pad row).

Two jitted programs per pool shape, sharing ``round._ROUND_CACHE``:

  * **admit** — vmapped local training of one dispatch group against the
    current global, written into the group's slot rows.  The host lays the
    group out in SLOT ORDER (client at pool slot j occupies row j of every
    stacked argument; pad spec elsewhere), so the program just selects
    ``where(written, trained, c_buf)`` row-wise — shard-local, zero
    collectives, unlike the earlier ``c_buf.at[slots].set`` runtime-index
    scatter that forced GSPMD to all-gather the whole pool (diagnosed by
    ``analysis/blame``, fixed in PR 8).  c_buf is donated so admissions
    ping-pong one allocation.
  * **merge** — ``flat.aggregate_buffers`` over the whole pool with the
    per-row staleness-discounted weights; g_buf is donated, the pool
    buffer is read-only (unmerged in-flight rows survive).

Admission is **lazily materialized**: a dispatched group only actually
trains at the first merge (or next dispatch) after it was handed out.
The global is unchanged between merges, so this is semantically identical
to training at dispatch time — and it is what makes the **parity fast
path** exact: a merge consuming one full fresh dispatch (every slot, all
arrived, staleness 0, nothing else resident) dispatches the *literal*
resident-round program ``round.flat_round`` — same program, same inputs,
bit-equal to ``run_rounds`` by construction (the scratch c_buf's values
are never a program input there).  ``tests/test_async_round.py`` pins
this, including malicious cohorts.

Simulated time comes from the source (``repro.sim``): the engine is a
deterministic event loop over (dispatch, arrival, deadline) events, so a
(seed, trace) pair replays bit-for-bit and the benchmark can gate
throughput ratios on simulated time.

Sharding: the slot pool lives in the resident 2-D P("data", "model")
``cohort_buffer_sharding`` layout END-TO-END between programs — each
device holds only its (rows/D, N/n_model) slice, the PR 6 follow-up (a)
the earlier whole-row layout deferred.  Two things make that possible:
the distributed two-stage trimmed quantile
(``kernels.fedfa_quantile.multilevel``) lets the merge's norms pass
consume N/n_model slices directly (histogram psums over ``model``, never
whole rows), and **grafting moved to admission time** — the trained rows
are naturally model-replicated whole rows inside the admit program, so
the data-dependent graft gather is shard-local there, and the merge runs
``flat.aggregate_buffers(pregrafted=True)``: 2-D, zero all-gathers, zero
re-layout collectives (see ``sharding.cohort.async_admit_shardings``).

Quantized admission (``fl.update_dtype`` int8/bf16): the pool becomes
the 4-tuple (x_q, scales, e_buf, e_scales) — rows are quantized at
admission time with per-(slot, segment) scales, the slot's server-side
error-feedback residual re-enters before quantizing, and the merge feeds
the quantized pool straight into the fused dequantize-aggregate
(``flat.aggregate_buffers(scales=...)``).  The resident pool bytes drop
~4x at int8 and the read-once / zero-all-gather structure is unchanged
(``quantized_admit_contract``).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core import flat
from repro.core import round as round_mod
from repro.core.fedfa import STRATEGIES
from repro.core.server import (ClientSpec, FLConfig, cohort_update,
                               default_class_masks, stack_runtimes)
from repro.models.masks import full_client
from repro.sharding import cohort as cohort_sh

Params = Any


@dataclass(frozen=True)
class AsyncConfig:
    """Slot-pool / staleness policy for the async engine.

    capacity       fixed number of real client slots in the pool
    merge_k        merge as soon as this many rows have arrived
                   (1 = fully async FedAsync-style; capacity = full-pool)
    staleness_max  rows older than this many global versions are DROPPED
                   (their influence is exactly zero — the bound)
    deadline       merge whatever has arrived after this much simulated
                   time since the last merge (inf = count-triggered only)
    discount       staleness weight shape: "rsqrt" (1/sqrt(1+s), FedBuff's
                   default) or "const" (1 up to the bound)
    retry_dt       simulated-time step while starved (no clients, none in
                   flight); max_retries consecutive starved steps raise.
    """
    capacity: int = 8
    merge_k: int = 4
    staleness_max: int = 4
    deadline: float = float("inf")
    discount: str = "rsqrt"
    retry_dt: float = 1.0
    max_retries: int = 1000

    def __post_init__(self):
        if self.capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {self.capacity}")
        if not 1 <= self.merge_k <= self.capacity:
            raise ValueError(
                f"merge_k must be in [1, capacity={self.capacity}], "
                f"got {self.merge_k}")
        if self.staleness_max < 0:
            raise ValueError("staleness_max must be >= 0")
        if self.discount not in ("rsqrt", "const"):
            raise ValueError(f"unknown discount {self.discount!r}")

    @classmethod
    def parity(cls, capacity: int) -> "AsyncConfig":
        """The parity-mode policy: full-pool merges, zero tolerated
        staleness, no deadline — with a full-cohort deterministic source
        (``sim.ParitySource``) every merge takes the fast path and the run
        is bit-equal to ``run_rounds``."""
        return cls(capacity=capacity, merge_k=capacity, staleness_max=0,
                   deadline=float("inf"))


def staleness_weight(s, acfg: AsyncConfig) -> np.ndarray:
    """(…,) staleness discount: w(0) = 1, decaying per ``acfg.discount``,
    exactly 0 beyond ``staleness_max`` (the bounded-staleness cutoff).
    Applied multiplicatively to ``n_data`` so stale clients keep their
    data-size weighting but lose influence with age."""
    s = np.asarray(s, np.float64)
    base = 1.0 / np.sqrt(1.0 + s) if acfg.discount == "rsqrt" \
        else np.ones_like(s)
    return np.where(s <= acfg.staleness_max, base, 0.0).astype(np.float32)


def admit_contract(index: flat.FlatIndex, mesh=None, *, rows: int):
    """Declared contract of the admit program: ZERO all-gathers.

    The donated pool buffer (flattened param 1; param 0 is the NON-donated
    g_buf) must alias — admissions ping-pong one allocation.  PR 7 had to
    pin <= 2 all-gathers here: the ``c_buf.at[slots].set`` scatter carried
    RUNTIME slot indices, so GSPMD could not prove it shard-local and
    re-gathered the full pool (``analysis/blame`` attributed both gathers
    to that one scatter line).  The host now lays each dispatch group out
    in slot order and the program writes rows with an elementwise
    ``where(written, ...)`` select — shard-local by construction, so the
    bound drops to exactly 0 and the pool never materializes anywhere
    (``full_cohort_gathers == 0`` over >= rows*N payloads).  The graft
    gather now runs here too (admission-time grafting, see the module
    docstring): it permutes rows of each client's own model-replicated
    trained buffer, shard-local along ``data``, so the bound stays 0.
    Peak budget ``(2 + 5*r) * N * 4`` bytes/device (r = pool rows per
    data shard): the grafted rows, the replicated global and the per-row
    training temporaries — measured ~5 N-multiples on the canonical
    fixture; the resident pool slice itself is only N/n_model wide."""
    from repro.analysis.contracts import Contract
    r = max(1, rows // cohort_sh.data_shards(mesh))
    return Contract(
        name="async/admit",
        description="admit: train dispatch group, select into pool slots",
        all_gathers=0, full_cohort_gathers=0,
        cohort_elems=rows * index.n_padded,
        peak_live_bytes_per_device=(None, (2 + 5 * r) * index.n_padded * 4),
        donated=frozenset({1}))


def merge_contract(index: flat.FlatIndex, mesh=None, *, rows: int):
    """Declared contract of the merge program: the bounded-staleness merge
    aggregates the 2-D P("data", "model") pool with ZERO all-gathers AND
    zero re-layout collectives — rows were grafted at admission, so the
    aggregation is 2-D end-to-end: no reduce-scatter, per-shard partial
    sums finished by N/n_model-sized psums plus the distributed quantile's
    histogram-plane psums over ``model`` (the all-reduce cap below).  The
    donated g_buf (param 0) must alias.  Peak budget ``(6 + 12*r) * N * 4``
    bytes/device like the aggregation contract (same tail; an upper bound —
    the 2-D path peaks well below it since rows stay N/n_model slices)."""
    from repro.analysis.contracts import Contract
    from repro.kernels.fedfa_quantile.multilevel import histogram_elems
    multi = mesh is not None and mesh.size > 1
    ms = cohort_sh.model_shards(mesh)
    r = max(1, rows // cohort_sh.data_shards(mesh))
    kw = {}
    if multi and ms == 1:
        kw = dict(scale_allreduces=(1, None), scale_elems=index.n_padded)
    elif multi:
        scale = index.n_padded // ms
        kw = dict(reduce_scatters=0, scale_allreduces=(1, 2),
                  scale_elems=scale,
                  allreduce_max_elems=max(
                      scale, histogram_elems(r, index.n_segments)))
    return Contract(
        name="async/merge" if ms <= 1 else f"async/merge-ms{ms}",
        description="merge: staleness-weighted aggregation over the pool",
        all_gathers=0,
        peak_live_bytes_per_device=(None, (6 + 12 * r) * index.n_padded * 4),
        donated=frozenset({0}), **kw)


def quantized_admit_contract(index: flat.FlatIndex, mesh=None, *, rows: int):
    """Declared contract of the QUANTIZED admit program (``--update-dtype
    int8``/``bf16``): the layout guarantees of ``admit_contract`` — zero
    all-gathers, zero full-cohort gathers, the shard-local row select —
    carry over with the pool split into four donated pieces (params 1-4:
    quantized rows, scales, error-feedback residual, residual scales), all
    ping-ponging their own allocation.  Quantize/EF adds no sort or top_k
    (``sorts == 0`` on the traced program — the per-segment max is a
    scatter-max, not a partition).  Peak budget ``(2 + 6*r) * N * 4``
    bytes/device: one extra f32 (r, N) tenant over the f32 admit's
    ``(2 + 5r)`` covers the error-feedback add + requantize chain —
    measured 5.5 N-multiples at r = 1 on the canonical 4-device fixture
    vs 4.95 for the f32 admit; the RESIDENT pool bytes between programs
    drop ~4x (int8 rows + int8 residuals + two small scale tables)."""
    from repro.analysis.contracts import Contract
    r = max(1, rows // cohort_sh.data_shards(mesh))
    return Contract(
        name="async/admit-quant",
        description="quantized admit: train, EF + quantize, select into "
                    "pool slots",
        all_gathers=0, full_cohort_gathers=0,
        cohort_elems=rows * index.n_padded,
        peak_live_bytes_per_device=(None, (2 + 6 * r) * index.n_padded * 4),
        donated=frozenset({1, 2, 3, 4}), sorts=0)


def make_admit_program(cfg: ArchConfig, fl: FLConfig, index: flat.FlatIndex,
                       *, any_malicious: bool, mesh=None, rows: int):
    """Build (or fetch) the jitted admit program for one pool shape:

      (g_buf (N,), c_buf (rows, N), masks, gates, gmaps, cms, mal,
       batches, keys, written (rows,) int32)
        -> (c_buf' (rows, N), losses (rows,))

    All stacked arguments arrive in SLOT ORDER (the engine places each
    dispatched client at its pool-slot row, pad spec elsewhere); the
    program trains every row against the CURRENT global, **grafts** it
    (Alg. 2, when the strategy grafts — the trained rows are still
    model-replicated whole rows here, so the data-dependent gather is
    shard-local; the merge then runs ``pregrafted=True`` and never needs
    whole rows) and keeps the grafted row where ``written`` is set, the
    existing pool row where it is not.  The select is elementwise along
    the sharded row axis, so it lowers with zero collectives — the
    re-gather the old runtime-index scatter forced is structurally
    impossible.  Rows are position-independent under vmap, so each
    client's update is bit-identical to the dispatch-ordered layout.
    c_buf is donated (admissions ping-pong one allocation) and lives in
    the 2-D P("data", "model") resident layout on both sides; g_buf is
    NOT donated (the merge donates it).  Cached in ``round._ROUND_CACHE``
    alongside the resident programs.
    """
    key = ("admit", index, cfg, round_mod._fl_static(fl),
           bool(any_malicious), round_mod._mesh_key(mesh), rows)
    fn = round_mod._ROUND_CACHE.get(key)
    if fn is not None:
        round_mod._ROUND_CACHE.move_to_end(key)
        return fn
    do_graft = bool(STRATEGIES[fl.strategy].get("graft", False))

    if fl.update_dtype != "f32":
        dens_fn = jax.vmap(functools.partial(flat._density_and_fraction,
                                             cfg, index))

        def _admit_q(g_buf, c_buf, s_buf, e_buf, es_buf, masks, gates,
                     gmaps, cms, mal, batches, keys, written):
            g = flat.unflatten(index, g_buf)
            updated, losses = cohort_update(
                g, cfg, fl, masks, gates, batches, cms, mal, keys,
                any_malicious=any_malicious)
            x = cohort_sh.constrain_cohort(
                flat.flatten_stacked(index, updated), mesh)
            if do_graft:
                x = cohort_sh.constrain_cohort(
                    jax.vmap(functools.partial(flat._graft_flat, index))(
                        x, gmaps), mesh)
            # quantize at admission (graft + density already applied, like
            # the resident quantized round): the slot's error-feedback
            # residual from its PREVIOUS admission re-enters first, then
            # the new residual replaces it — both only where ``written``;
            # unwritten slots keep all four pool pieces untouched, so
            # in-flight rows and their pending residuals survive.  The
            # density mask wraps the whole sum so a previous occupant's
            # residual cannot leak outside the new client's subspace
            # (coordinates with density 0 carry γ weight 0)
            dens, _ = dens_fn(masks)
            y = (x + flat.dequantize_cohort(index, e_buf, es_buf)) \
                * cohort_sh.constrain_cohort(dens, mesh)
            x_q, scales = flat.quantize_cohort(index, y, fl.update_dtype)
            e = y - flat.dequantize_cohort(index, x_q, scales)
            e_q, e_s = flat.quantize_cohort(index, e, fl.update_dtype)
            wr = (written != 0)
            c_new = jnp.where(wr[:, None], x_q, c_buf)
            s_new = jnp.where(wr[:, None], scales, s_buf)
            e_new = jnp.where(wr[:, None], e_q, e_buf)
            es_new = jnp.where(wr[:, None], e_s, es_buf)
            return (cohort_sh.constrain_cohort_buffer(c_new, mesh), s_new,
                    cohort_sh.constrain_cohort_buffer(e_new, mesh), es_new,
                    losses)

        jit_kw = {}
        if mesh is not None:
            jit_kw["in_shardings"], jit_kw["out_shardings"] = \
                cohort_sh.quantized_admit_shardings(mesh)
        fn = jax.jit(_admit_q, donate_argnums=(1, 2, 3, 4), **jit_kw)
        round_mod._ROUND_CACHE[key] = fn
        while len(round_mod._ROUND_CACHE) > round_mod._ROUND_CACHE_MAX:
            round_mod._ROUND_CACHE.popitem(last=False)
        return fn

    def _admit(g_buf, c_buf, masks, gates, gmaps, cms, mal, batches, keys,
               written):
        g = flat.unflatten(index, g_buf)
        updated, losses = cohort_update(
            g, cfg, fl, masks, gates, batches, cms, mal, keys,
            any_malicious=any_malicious)
        x = cohort_sh.constrain_cohort(
            flat.flatten_stacked(index, updated), mesh)
        if do_graft:
            x = cohort_sh.constrain_cohort(
                jax.vmap(functools.partial(flat._graft_flat, index))(
                    x, gmaps), mesh)
        c_new = jnp.where((written != 0)[:, None], x, c_buf)
        return cohort_sh.constrain_cohort_buffer(c_new, mesh), losses

    jit_kw = {}
    if mesh is not None:
        jit_kw["in_shardings"], jit_kw["out_shardings"] = \
            cohort_sh.async_admit_shardings(mesh)
    fn = jax.jit(_admit, donate_argnums=(1,), **jit_kw)
    round_mod._ROUND_CACHE[key] = fn
    while len(round_mod._ROUND_CACHE) > round_mod._ROUND_CACHE_MAX:
        round_mod._ROUND_CACHE.popitem(last=False)
    return fn


def make_merge_program(cfg: ArchConfig, fl: FLConfig, index: flat.FlatIndex,
                       *, mesh=None, rows: int):
    """Build (or fetch) the jitted merge program:

      (g_buf (N,), c_buf (rows, N), masks, gates, gmaps, w (rows,))
        -> g_buf' (N,)

    ``flat.aggregate_buffers`` over the whole pool with the per-row
    staleness-discounted weights ``w`` as the ``nd`` argument — free /
    unarrived / over-stale rows carry w = 0 and are inert in the trimmed
    norms and α, exactly like mesh pad rows.  Rows were already grafted by
    the admit program, so the merge declares ``pregrafted=True``: graft-on
    weighting semantics without the gather, and the pool's 2-D
    P("data", "model") layout is consumed directly (no re-layout).  g_buf
    is donated; the pool buffer is read-only so in-flight rows survive the
    merge.
    """
    key = ("merge", index, cfg, round_mod._fl_static(fl),
           round_mod._mesh_key(mesh), rows)
    fn = round_mod._ROUND_CACHE.get(key)
    if fn is not None:
        round_mod._ROUND_CACHE.move_to_end(key)
        return fn
    kw = STRATEGIES[fl.strategy]

    if fl.update_dtype != "f32":
        def _merge_q(g_buf, c_buf, s_buf, masks, gates, gmaps, w):
            x = cohort_sh.constrain_cohort_buffer(c_buf, mesh)
            return flat.aggregate_buffers(
                index, g_buf, x, cfg, masks, gates, gmaps, w, trim=fl.trim,
                pregrafted=True, scales=s_buf, use_kernel=fl.use_kernel,
                interpret=fl.interpret, mesh=mesh, **kw)

        jit_kw = {}
        if mesh is not None:
            jit_kw["in_shardings"], jit_kw["out_shardings"] = \
                cohort_sh.quantized_merge_shardings(mesh)
        fn = jax.jit(_merge_q, donate_argnums=(0,), **jit_kw)
        round_mod._ROUND_CACHE[key] = fn
        while len(round_mod._ROUND_CACHE) > round_mod._ROUND_CACHE_MAX:
            round_mod._ROUND_CACHE.popitem(last=False)
        return fn

    def _merge(g_buf, c_buf, masks, gates, gmaps, w):
        x = cohort_sh.constrain_cohort_buffer(c_buf, mesh)
        return flat.aggregate_buffers(
            index, g_buf, x, cfg, masks, gates, gmaps, w, trim=fl.trim,
            pregrafted=True, use_kernel=fl.use_kernel,
            interpret=fl.interpret, mesh=mesh, **kw)

    jit_kw = {}
    if mesh is not None:
        jit_kw["in_shardings"], jit_kw["out_shardings"] = \
            cohort_sh.async_merge_shardings(mesh)
    fn = jax.jit(_merge, donate_argnums=(0,), **jit_kw)
    round_mod._ROUND_CACHE[key] = fn
    while len(round_mod._ROUND_CACHE) > round_mod._ROUND_CACHE_MAX:
        round_mod._ROUND_CACHE.popitem(last=False)
    return fn


class SlotPool:
    """Host-side bookkeeping for the (rows, N) device pool.

    ``capacity`` real slots; rows with id >= capacity are the mesh pad
    rows — permanently free, never dispatched into, always weight 0.
    """

    def __init__(self, capacity: int, rows: int):
        self.capacity, self.rows = int(capacity), int(rows)
        self.occupied = np.zeros(rows, bool)
        self.arrival = np.full(rows, np.inf)
        self.version = np.zeros(rows, np.int64)
        self.nd = np.zeros(rows, np.float32)
        self.loss = np.full(rows, np.nan, np.float32)
        self.specs: List[Optional[ClientSpec]] = [None] * rows

    def free_slots(self) -> np.ndarray:
        return np.flatnonzero(~self.occupied[:self.capacity])

    def ready(self, now: float) -> np.ndarray:
        return self.occupied & (self.arrival <= now)

    def admit(self, slots: np.ndarray, specs: Sequence[ClientSpec],
              latencies: np.ndarray, now: float, version: int) -> None:
        self.occupied[slots] = True
        self.arrival[slots] = now + np.asarray(latencies, np.float64)
        self.version[slots] = version
        self.nd[slots] = [float(s.n_data) for s in specs]
        self.loss[slots] = np.nan
        for i, s in zip(slots, specs):
            self.specs[int(i)] = s

    def release(self, mask: np.ndarray) -> None:
        self.occupied[mask] = False
        self.arrival[mask] = np.inf
        self.nd[mask] = 0.0
        for i in np.flatnonzero(mask):
            self.specs[int(i)] = None


class AsyncEngine:
    """Deterministic event loop over (dispatch, arrival, deadline) events.

    Construct with the flattened global buffer, then drive ``step()`` until
    enough merges happened (``run_async`` does this).  Host state only —
    all device work goes through the admit / merge / parity programs.

    ``on_merge`` (optional) receives a host-side snapshot dict per merge
    ({"x", "w", "specs", "g_before", "g_after", "loss"}, rows aligned) —
    the differential oracle re-aggregates it with the tree engine.
    """

    def __init__(self, g_buf: jax.Array, cfg: ArchConfig, fl: FLConfig,
                 index: flat.FlatIndex, source: Callable, key, *,
                 acfg: AsyncConfig, mesh=None,
                 on_merge: Optional[Callable[[dict], None]] = None):
        self.cfg, self.fl, self.index, self.mesh = cfg, fl, index, mesh
        self.source, self.key, self.acfg = source, key, acfg
        self.on_merge = on_merge
        self.rows = acfg.capacity + cohort_sh.pad_rows(acfg.capacity, mesh)
        self.pool = SlotPool(acfg.capacity, self.rows)
        self.g_buf = g_buf
        # f32: one (rows, N) pool; quantized admission dtype: the 4-tuple
        # (x_q, scales, e_buf, e_scales) — same convention as flat_round
        self._qmode = fl.update_dtype != "f32"
        self._c_buf: Optional[Any] = None
        # simulated clock + counters (the benchmark gates on `now`)
        self.now = 0.0
        self.version = 0          # bumps once per successful merge
        self.dispatch_idx = 0
        self.last_merge_t = 0.0
        self.merges = 0
        self.merged_rows = 0
        self.dropped_rows = 0     # over-stale rows whose influence was 0
        self._pending = None      # latest un-materialized dispatch group
        self._retries = 0
        self._pad_spec = ClientSpec(arch=full_client(cfg), n_data=0)

    # -- event loop --------------------------------------------------------

    def step(self) -> Optional[float]:
        """Advance by one event; returns the merge's mean loss when this
        step merged, else None."""
        free = self.pool.free_slots()
        if free.size:
            res = self.source(self.dispatch_idx, self.now, int(free.size))
            if res is not None and len(res[0]) > 0:
                self._dispatch(free, *res)
                return None
        ready = self.pool.ready(self.now)
        n_ready = int(ready.sum())
        deadline_t = self.last_merge_t + self.acfg.deadline
        if n_ready >= self.acfg.merge_k or \
                (self.now >= deadline_t and n_ready >= 1):
            return self._merge(ready)
        if self.now >= deadline_t:
            # deadline fired over an empty ready set: re-arm, not a merge
            self.last_merge_t = self.now
            return None
        # advance simulated time to the next event
        inflight = self.pool.occupied & (self.pool.arrival > self.now)
        targets = []
        if inflight.any():
            targets.append(float(self.pool.arrival[inflight].min()))
        if np.isfinite(self.acfg.deadline) and self.pool.occupied.any():
            targets.append(deadline_t)
        if targets:
            self.now = max(self.now, min(targets))
            self._retries = 0
            return None
        # nothing in flight and the source had nothing: starved
        self._retries += 1
        if self._retries > self.acfg.max_retries:
            raise RuntimeError(
                f"async engine starved: source produced no clients for "
                f"{self._retries} consecutive retries (sim t={self.now:g})")
        self.now += self.acfg.retry_dt
        return None

    def _dispatch(self, free: np.ndarray, specs, batches, latencies) -> None:
        b = len(specs)
        if b > free.size:
            raise ValueError(
                f"source returned {b} clients for {free.size} free slots")
        slots = free[:b]
        # a dispatch group trains lazily at the first merge after it was
        # handed out; a SECOND dispatch before that merge materializes the
        # first (both train against the same global version, so order
        # within the inter-merge window is irrelevant)
        self._materialize()
        gkey = jax.random.fold_in(self.key, self.dispatch_idx)
        self._pending = (slots, list(specs), batches, gkey)
        self.pool.admit(slots, specs, np.asarray(latencies, np.float64),
                        self.now, self.version)
        self.dispatch_idx += 1
        self._retries = 0

    # -- device programs ---------------------------------------------------

    def _ensure_cbuf(self) -> None:
        c = self._c_buf
        if self._qmode:
            want = flat.update_dtype_of(self.fl.update_dtype)
            if round_mod._quant_state_ok(c, self.rows, want):
                return
            c = round_mod.fresh_quant_state(self.index, self.rows,
                                            self.fl.update_dtype)
            if self.mesh is not None:
                cb = cohort_sh.cohort_buffer_sharding(self.mesh)
                co = cohort_sh.cohort_sharding(self.mesh)
                c = tuple(jax.device_put(b, s)
                          for b, s in zip(c, (cb, co, cb, co)))
            self._c_buf = c
            return
        if c is None or isinstance(c, tuple) \
                or c.is_deleted() or c.shape[0] != self.rows:
            c = jnp.zeros((self.rows, self.index.n_padded), jnp.float32)
            if self.mesh is not None:
                c = jax.device_put(
                    c, cohort_sh.cohort_buffer_sharding(self.mesh))
            self._c_buf = c

    def _pool_x(self) -> np.ndarray:
        """Host f32 view of the pool rows for ``on_merge`` snapshots —
        dequantized in qmode (density is a 0/1 mask already baked into the
        stored values; re-applying it downstream is idempotent)."""
        if self._qmode:
            return np.asarray(flat.dequantize_cohort(
                self.index, self._c_buf[0], self._c_buf[1]))
        return np.asarray(self._c_buf)

    def _materialize(self) -> None:
        """Run the admit program for the pending dispatch group (if any):
        train it against the current global and scatter into its slots."""
        if self._pending is None:
            return
        slots, specs, batches, gkey = self._pending
        self._pending = None
        b = len(specs)
        slots = np.asarray(slots)
        # slot-ordered layout: row j of every stacked argument belongs to
        # pool slot j — the dispatched client at slot j lands on row j, all
        # other rows carry the pad spec.  vmapped rows are position-
        # independent, so each client trains the same bits as the old
        # dispatch-ordered layout; the program then overwrites exactly the
        # ``written`` rows with a shard-local select (no runtime-index
        # scatter, no GSPMD re-gather — see admit_contract).
        order = np.full(self.rows, b, np.int64)  # unwritten rows -> pad entry
        order[slots] = np.arange(b)
        slot_specs = [self._pad_spec] * self.rows
        for i, j in enumerate(slots):
            slot_specs[int(j)] = specs[i]
        masks, gates, gmaps, _nd, cms, mal = \
            stack_runtimes(self.cfg, slot_specs)
        cms_in = default_class_masks(cms, self.cfg, self.fl, self.rows)
        # host-side per-client keys: client i keeps split(gkey)[i] wherever
        # its slot row lands; unwritten rows reuse key 0 (the resident
        # round's pad-row convention)
        keys_b = jax.random.split(gkey, b)
        keys = jnp.concatenate([keys_b, keys_b[:1]])[order]
        batches_row = jax.tree.map(
            lambda a: jnp.concatenate(
                [a, jnp.broadcast_to(a[:1], (1,) + a.shape[1:])])[order],
            batches)
        written = np.zeros(self.rows, np.int32)
        written[slots] = 1
        fn = make_admit_program(
            self.cfg, self.fl, self.index,
            any_malicious=any(s.malicious for s in specs),
            mesh=self.mesh, rows=self.rows)
        self._ensure_cbuf()
        if self._qmode:
            out = fn(self.g_buf, *self._c_buf, masks, gates, gmaps, cms_in,
                     mal, batches_row, keys, jnp.asarray(written))
            self._c_buf, losses = tuple(out[:4]), out[4]
        else:
            self._c_buf, losses = fn(self.g_buf, self._c_buf, masks, gates,
                                     gmaps, cms_in, mal, batches_row, keys,
                                     jnp.asarray(written))
        self.pool.loss[slots] = np.asarray(losses)[slots]

    def _merge(self, ready: np.ndarray) -> Optional[float]:
        pool, acfg = self.pool, self.acfg
        if self._pending is not None:
            slots, specs, batches, gkey = self._pending
            if (len(specs) == pool.capacity
                    and bool(ready[slots].all())
                    and int(pool.occupied.sum()) == pool.capacity
                    and bool((pool.version[slots] == self.version).all())):
                return self._merge_parity(slots, specs, batches, gkey)
        self._materialize()
        s = self.version - pool.version          # (rows,) staleness
        keep = ready & (s <= acfg.staleness_max)
        overstale = ready & ~keep
        if not keep.any():
            # every arrived row exceeded the bound: drop them (influence
            # exactly 0), re-arm the deadline — NOT a merge
            self.dropped_rows += int(overstale.sum())
            pool.release(overstale)
            self.last_merge_t = self.now
            return None
        w = np.zeros(self.rows, np.float32)
        w[keep] = pool.nd[keep] * staleness_weight(s[keep], acfg)
        slot_specs = [pool.specs[i] if pool.occupied[i] else self._pad_spec
                      for i in range(self.rows)]
        masks, gates, gmaps, _nd, _cms, _mal = \
            stack_runtimes(self.cfg, slot_specs)
        fn = make_merge_program(self.cfg, self.fl, self.index,
                                mesh=self.mesh, rows=self.rows)
        g_prev = np.asarray(self.g_buf) if self.on_merge else None
        self._ensure_cbuf()
        if self._qmode:
            self.g_buf = fn(self.g_buf, self._c_buf[0], self._c_buf[1],
                            masks, gates, gmaps, jnp.asarray(w))
        else:
            self.g_buf = fn(self.g_buf, self._c_buf, masks, gates, gmaps,
                            jnp.asarray(w))
        loss = float(np.nanmean(pool.loss[keep]))
        if self.on_merge:
            # pool rows were grafted at admission (when the strategy
            # grafts) — re-aggregating the snapshot must NOT graft again
            self.on_merge({"x": self._pool_x(), "w": w.copy(),
                           "specs": slot_specs, "g_before": g_prev,
                           "g_after": np.asarray(self.g_buf), "loss": loss,
                           "pregrafted": bool(
                               STRATEGIES[self.fl.strategy].get("graft"))})
        self.merged_rows += int(keep.sum())
        self.dropped_rows += int(overstale.sum())
        pool.release(ready)                      # over-stale rows too
        self.version += 1
        self.merges += 1
        self.last_merge_t = self.now
        return loss

    def _merge_parity(self, slots, specs, batches, gkey) -> float:
        """Parity fast path: this merge consumes exactly one full fresh
        dispatch (every slot, all arrived, staleness 0, nothing else in
        the pool) — dispatch the LITERAL resident-round program, which is
        bit-equal to ``run_rounds`` by construction (same cached program,
        same inputs; the scratch c_buf's values are not a program input)."""
        pool = self.pool
        self._pending = None
        g_prev = np.asarray(self.g_buf) if self.on_merge else None
        runtimes = stack_runtimes(self.cfg, specs)
        self.g_buf, self._c_buf, loss = round_mod.flat_round(
            self.g_buf, self._c_buf, self.cfg, self.fl, self.index,
            runtimes, batches, gkey,
            any_malicious=any(s.malicious for s in specs), mesh=self.mesh)
        lossf = float(loss)
        if self.on_merge:
            # flat_round orders rows by spec; in the parity flow slots are
            # exactly [0..capacity) so rows align with the general path
            w = np.zeros(self.rows, np.float32)
            w[np.asarray(slots)] = [float(s.n_data) for s in specs]
            slot_specs = list(specs) + \
                [self._pad_spec] * (self.rows - len(specs))
            # the f32 resident round grafts inside its own aggregation —
            # the scratch rows it returns are UNgrafted; the QUANTIZED
            # round grafts before quantizing, so its pool rows are grafted
            self.on_merge({"x": self._pool_x(), "w": w,
                           "specs": slot_specs, "g_before": g_prev,
                           "g_after": np.asarray(self.g_buf),
                           "loss": lossf, "pregrafted": self._qmode and
                           bool(STRATEGIES[self.fl.strategy].get("graft"))})
        self.merged_rows += len(specs)
        pool.release(pool.occupied.copy())
        self.version += 1
        self.merges += 1
        self.last_merge_t = self.now
        return lossf


def run_async(global_params: Params, cfg: ArchConfig, fl: FLConfig,
              merges: int, source: Callable, key, *,
              acfg: Optional[AsyncConfig] = None, eval_every: int = 5,
              eval_fn: Optional[Callable[[int, float, Params], None]] = None,
              ckpt_path: Optional[str] = None, mesh=None,
              on_merge: Optional[Callable[[dict], None]] = None
              ) -> Tuple[Params, List[float]]:
    """Drive the async engine until ``merges`` merges completed.

    ``source(dispatch_idx, sim_time, k)`` supplies arriving clients (see
    ``repro.sim.source``).  Eval/checkpoint fire at the shared
    ``round.eval_boundary`` merge indices; losses are per-merge means over
    the rows actually merged, converted to host floats as they happen.
    Returns (final params tree, per-merge losses).  ``merges <= 0`` is a
    clean no-op, like ``run_rounds``.
    """
    if merges <= 0:
        return global_params, []
    acfg = acfg or AsyncConfig()
    index = flat.get_index(global_params, pad_to=cohort_sh.pad_unit(mesh))
    g_buf = flat.flatten(index, global_params)
    if mesh is not None:
        g_buf = jax.device_put(g_buf, cohort_sh.global_sharding(mesh))
    eng = AsyncEngine(g_buf, cfg, fl, index, source, key, acfg=acfg,
                      mesh=mesh, on_merge=on_merge)
    losses: List[float] = []
    # belt-and-braces bound on non-merging steps (true starvation already
    # raises inside step(); this catches policy livelocks)
    max_steps = (merges + 1) * (acfg.max_retries + 16 * (eng.rows + 2))
    steps = 0
    while eng.merges < merges:
        loss = eng.step()
        steps += 1
        if steps > max_steps:
            raise RuntimeError(
                f"async engine made only {eng.merges}/{merges} merges in "
                f"{steps} steps — policy livelock?")
        if loss is None:
            continue
        r = eng.merges - 1
        losses.append(loss)
        if round_mod.eval_boundary(r, merges, eval_every):
            if eval_fn is not None:
                eval_fn(r, loss, flat.unflatten(index, eng.g_buf))
            if ckpt_path is not None:
                from repro.checkpoint import checkpoint as ckpt_mod
                ckpt_mod.save_from_buffer(
                    f"{ckpt_path}_m{r:05d}", index, eng.g_buf,
                    meta={"merge": r, "strategy": fl.strategy,
                          "sim_time": eng.now})
    return flat.unflatten(index, eng.g_buf), losses
