"""FedFA server-side machinery: layer grafting (Alg. 2), global model
distribution (Alg. 3), and scalable aggregation (Alg. 1).

The per-leaf tree engine here is ORACLE-ONLY: the production aggregation
path is the flat engine (``repro.core.flat``, ``engine="flat"``, the
default everywhere).  The tree implementation is kept as an
independently-written Alg. 1 that the flat engine is differentially tested
against (``tests/test_differential_oracle.py``); do not build new features
on it.

Memory-conscious design: the accumulation over clients runs as a
``lax.scan`` with (M', γ) carry — only two global-model-sized buffers live
at once regardless of cohort size — and the per-client trimmed-norm pass is
a separate scan.  Under pjit with the client axis sharded over the mesh's
``data`` axis the scans become the server's collective reduction.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.tree_util import tree_map_with_path

from repro.configs.base import ArchConfig
from repro.core.masking import (AX, active_fraction, apply_mask_tree,
                                axis_mask_tree, mask_density)
from repro.models.masks import WidthMasks

Params = Dict[str, Any]
_IS_AX = lambda x: isinstance(x, AX)


# ---------------------------------------------------------------------------
# Alg. 2 — layer grafting (gather along the repeat axis)
# ---------------------------------------------------------------------------

def graft_stage0(params: Params, graft_map: jax.Array) -> Params:
    """Replicate the last active block of each section into missing slots."""
    st = params["stages"]
    s0 = jax.tree.map(lambda x: jnp.take(x, graft_map, axis=0), st[0])
    return dict(params, stages=(s0,) + tuple(st[1:]))


# ---------------------------------------------------------------------------
# Alg. 3 — global model distribution (width masking; depth via gates)
# ---------------------------------------------------------------------------

def extract_client_model(global_params: Params, cfg: ArchConfig,
                         masks: WidthMasks) -> Params:
    """Server -> client: zero channels outside the client's width. Depth
    reduction is positional (clients run the first d_s blocks per section),
    so no parameter surgery is needed beyond the width mask."""
    return apply_mask_tree(global_params, axis_mask_tree(cfg, masks))


# ---------------------------------------------------------------------------
# §4.3 — trimmed norms and scaling factors
# ---------------------------------------------------------------------------

def _path_stage_info(path) -> Tuple[bool, Optional[int]]:
    """(is_depth_stacked, stage_index or None for encoder blocks)."""
    def key_of(e):
        return getattr(e, "key", getattr(e, "idx", None))
    k0 = key_of(path[0])
    if k0 == "stages":
        return True, key_of(path[1])
    if k0 == "encoder" and key_of(path[1]) == "blocks":
        return True, None
    return False, None


def trimmed_sq_norms(params: Params, axtree: Params, trim: float = 0.95) -> Params:
    """Per-layer L2 norm of weights with |w| below the ``trim`` quantile.

    Masked (inactive) entries are excluded from the quantile by shifting the
    quantile level: with active fraction f, the ``trim`` quantile of active
    magnitudes equals the ``1 - (1-trim)*f`` quantile of the zero-padded
    tensor.  Returns (R,) per depth-stacked leaf, scalar otherwise.
    """
    def f(path, w, ax):
        fa = active_fraction(ax)
        q = 1.0 - (1.0 - trim) * fa
        stacked, _ = _path_stage_info(path)
        lead = w.shape[0] if stacked else 1
        wf = jnp.abs(w.reshape(lead, -1).astype(jnp.float32))
        t = jnp.quantile(wf, q, axis=-1, keepdims=True)
        ss = jnp.sum(jnp.where(wf <= t, wf * wf, 0.0), axis=-1)
        n = jnp.sqrt(ss)
        return n if stacked else n[0]
    return tree_map_with_path(f, params, axtree, is_leaf=_IS_AX)


def scaling_factors(norms_stacked: Params, eps: float = 1e-12,
                    n_data=None) -> Params:
    """α_c^(l) = mean_κ ||M95,κ^(l)|| / ||M95,c^(l)|| from stacked norms
    (leading axis = clients).

    With ``n_data`` given, the mean is over clients with data only —
    zero-weight rows (γ = 0 in the accumulation, e.g. the sharded round's
    pad rows) must not shift everyone else's α.  Matches the flat engine's
    validity-weighted mean; identical to the plain mean when every client
    has data."""
    if n_data is None:
        valid = None
    else:
        valid = (n_data > 0).astype(jnp.float32)
        denom = jnp.maximum(jnp.sum(valid), 1.0)

    def f(n):
        if valid is None:
            mean = jnp.mean(n, axis=0, keepdims=True)
        else:
            w = valid.reshape((-1,) + (1,) * (n.ndim - 1))
            mean = jnp.sum(w * n, axis=0, keepdims=True) / denom
        return mean / jnp.maximum(n, eps)
    return jax.tree.map(f, norms_stacked)


# ---------------------------------------------------------------------------
# Alg. 1 — aggregation
# ---------------------------------------------------------------------------

def _weighted_contribution(cfg: ArchConfig, p_c: Params, masks_c: WidthMasks,
                           gmap_c, gate_c, nd_c, alpha_c: Optional[Params],
                           graft: bool):
    """One client's (N_c·α_c·M_c, N_c·mask) pair, fully masked/grafted."""
    ax = axis_mask_tree(cfg, masks_c)
    if graft:
        p_c = graft_stage0(p_c, gmap_c)
        depthw = jnp.ones_like(gate_c)
    else:
        depthw = gate_c

    def depth_weight(path, w):
        stacked, stage = _path_stage_info(path)
        if stacked and stage == 0:
            return depthw.reshape((-1,) + (1,) * (w.ndim - 1))
        return jnp.ones((), jnp.float32)

    def f_contrib(path, w, axl, al):
        wf = w.astype(jnp.float32) * mask_density(w.shape, axl)
        if al is not None:
            a = al.reshape(al.shape + (1,) * (w.ndim - al.ndim))
            wf = wf * a
        return nd_c * depth_weight(path, w) * wf

    def f_gamma(path, w, axl):
        dens = mask_density(w.shape, axl)
        return (nd_c * depth_weight(path, w) * dens) * jnp.ones(w.shape, jnp.float32)

    if alpha_c is None:
        contrib = tree_map_with_path(
            lambda pth, w, axl: f_contrib(pth, w, axl, None),
            p_c, ax, is_leaf=_IS_AX)
    else:
        contrib = tree_map_with_path(f_contrib, p_c, ax, alpha_c, is_leaf=_IS_AX)
    gamma = tree_map_with_path(f_gamma, p_c, ax, is_leaf=_IS_AX)
    return contrib, gamma


def aggregate(global_params: Params, stacked_params: Params, cfg: ArchConfig,
              masks: WidthMasks, gates: jax.Array, gmaps: jax.Array,
              n_data: jax.Array, *, graft: bool = True, scale: bool = True,
              trim: float = 0.95, eps: float = 1e-12, engine: str = "tree",
              use_kernel: Optional[bool] = None,
              interpret: bool = False) -> Params:
    """FedFA Alg. 1 lines 11-24 (graft=scale=True) and the partial-
    aggregation baselines HeteroFL/FlexiFed/NeFL (graft=scale=False).

    stacked_params / masks / gates / gmaps / n_data carry a leading client
    axis m.  Returns the new global model; elements no client updated keep
    their previous global value (γ = 0 case).

    engine="flat" (the production path) runs Alg. 1 on one contiguous
    (m, N) buffer with fused segment kernels (repro.core.flat), dispatching
    to the Pallas fedfa_agg/fedfa_quantile kernels on TPU;
    use_kernel/interpret are flat-engine knobs.  engine="tree" is the
    original per-leaf tree-map/scan implementation, kept as a test-only
    differential oracle — slower, and not maintained for new features.
    """
    if engine == "flat":
        from repro.core import flat
        return flat.aggregate_flat(
            global_params, stacked_params, cfg, masks, gates, gmaps, n_data,
            graft=graft, scale=scale, trim=trim, eps=eps,
            use_kernel=use_kernel, interpret=interpret)
    if engine != "tree":
        raise ValueError(f"unknown aggregation engine {engine!r}")
    alphas = None
    if scale:
        def norm_body(_, xs):
            p_c, mk_c, gm_c = xs
            ax = axis_mask_tree(cfg, mk_c)
            p = graft_stage0(p_c, gm_c) if graft else p_c
            p = apply_mask_tree(p, ax)
            return _, trimmed_sq_norms(p, ax, trim)
        _, norms = jax.lax.scan(norm_body, None, (stacked_params, masks, gmaps))
        alphas = scaling_factors(norms, eps, n_data=n_data)

    def acc_body(carry, xs):
        Mp, Gm = carry
        if scale:
            p_c, mk_c, gm_c, gate_c, nd_c, al_c = xs
        else:
            p_c, mk_c, gm_c, gate_c, nd_c = xs
            al_c = None
        contrib, gamma = _weighted_contribution(
            cfg, p_c, mk_c, gm_c, gate_c, nd_c, al_c, graft)
        Mp = jax.tree.map(jnp.add, Mp, contrib)
        Gm = jax.tree.map(jnp.add, Gm, gamma)
        return (Mp, Gm), None

    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                         jax.tree.map(lambda x: x[0], stacked_params))
    xs = (stacked_params, masks, gmaps, gates, n_data)
    if scale:
        xs = xs + (alphas,)
    (Mp, Gm), _ = jax.lax.scan(acc_body, (zeros, zeros), xs)

    def finish(g_old, mp, gm):
        upd = mp / jnp.maximum(gm, eps)
        return jnp.where(gm > 0, upd, g_old.astype(jnp.float32)).astype(g_old.dtype)
    return jax.tree.map(finish, global_params, Mp, Gm)


# Strategy presets ----------------------------------------------------------

STRATEGIES = {
    # paper's method, all three flexibility modes share the same aggregation
    "fedfa": dict(graft=True, scale=True),
    # prior work: partial (incomplete) aggregation, no grafting, no scaling
    "heterofl": dict(graft=False, scale=False),
    "flexifed": dict(graft=False, scale=False),
    "nefl": dict(graft=False, scale=False),
    "fedavg": dict(graft=False, scale=False),
    # ablations
    "fedfa-graft-only": dict(graft=True, scale=False),
    "fedfa-scale-only": dict(graft=False, scale=True),
}


def aggregate_strategy(name: str, *args, **kw) -> Params:
    return aggregate(*args, **STRATEGIES[name], **kw)
