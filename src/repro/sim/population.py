"""Trace-driven client-population simulator (host-side, pure numpy).

The paper's premise is clients "ranging from powerful servers to mobile
devices"; the async round engine (``repro.core.async_round``) needs that
heterogeneity as *traces*: which of millions of registered clients are
available at simulated time t, and how long each takes to return an update
once dispatched.  This module models a registered population whose
per-client attributes — device class, latency distribution, availability
phase — are **derived, not stored**: a splitmix64-style hash of
``(seed, client id, salt)`` yields every attribute on demand, so a
population of millions costs a few scalars and sampling a cohort is one
vectorized pass over candidate ids.  Everything is deterministic in
``(seed, t, nonce)`` — the same trace replays bit-for-bit, which is what
lets the benchmark gate throughput ratios and the parity tests pin exact
schedules.

Device classes follow the HeteroFL-style skew the async engine must
survive: a few fast servers, a long tail of slow mobile devices whose
lognormal latencies produce the stragglers that stall synchronous rounds.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence, Tuple

import numpy as np

# hash salts (arbitrary odd constants) separating the attribute streams
_SALT_CLASS = 0x9e3779b97f4a7c15
_SALT_PHASE = 0xc2b2ae3d27d4eb4f
_SALT_AVAIL = 0x165667b19e3779f9
_SALT_LAT_A = 0x27d4eb2f165667c5
_SALT_LAT_B = 0x85ebca6b2b2ae35d


def _mix(x: np.ndarray) -> np.ndarray:
    """splitmix64 finalizer — vectorized uint64 -> uint64 (wrapping; the
    errstate silences numpy's scalar-overflow warning, wraparound is the
    point of the finalizer)."""
    x = np.asarray(x, np.uint64)
    with np.errstate(over="ignore"):
        x = (x ^ (x >> np.uint64(30))) * np.uint64(0xbf58476d1ce4e5b9)
        x = (x ^ (x >> np.uint64(27))) * np.uint64(0x94d049bb133111eb)
    return x ^ (x >> np.uint64(31))


def _u01(h: np.ndarray) -> np.ndarray:
    """uint64 hash -> uniform float64 in [0, 1)."""
    return (h >> np.uint64(11)).astype(np.float64) * (1.0 / (1 << 53))


@dataclass(frozen=True)
class DeviceClass:
    """One hardware tier of the registered population.

    ``lat_mu``/``lat_sigma`` parameterize a lognormal round-trip latency
    (dispatch -> update arrival, simulated seconds); ``avail`` is the base
    probability the device is reachable at any instant (modulated by a
    per-client diurnal phase); ``width_mult`` is the client architecture
    width this tier can afford (ties the latency skew to the paper's
    flexible-architecture axis — slow devices run thin models).
    """
    name: str
    weight: float          # population share
    lat_mu: float          # log-space mean of the lognormal latency
    lat_sigma: float       # log-space std
    avail: float           # base availability probability
    width_mult: float      # architecture width this class trains


# a skewed default fleet: stragglers are the 30% mobile_lo tail whose
# median latency is 30x the servers' with a heavy (sigma = 1) upper tail
DEFAULT_CLASSES: Tuple[DeviceClass, ...] = (
    DeviceClass("server", 0.05, np.log(2.0), 0.20, 0.95, 1.0),
    DeviceClass("desktop", 0.25, np.log(8.0), 0.40, 0.70, 0.75),
    DeviceClass("mobile_hi", 0.40, np.log(20.0), 0.60, 0.45, 0.5),
    DeviceClass("mobile_lo", 0.30, np.log(60.0), 1.00, 0.30, 0.25),
)


class ClientPopulation:
    """Millions of registered clients with trace-derived attributes.

    No per-client state is materialized: ``device_class``, ``latency`` and
    ``available`` hash the client id (with the population seed and a salt)
    into the attribute, so construction is O(#classes) and every query is
    vectorized over the requested ids.
    """

    def __init__(self, n_clients: int,
                 classes: Sequence[DeviceClass] = DEFAULT_CLASSES,
                 seed: int = 0, day: float = 1440.0):
        if n_clients < 1:
            raise ValueError(f"population needs >= 1 client, got {n_clients}")
        self.n_clients = int(n_clients)
        self.classes = tuple(classes)
        self.seed = np.uint64(seed)
        self.day = float(day)          # diurnal availability period (sim s)
        w = np.asarray([c.weight for c in self.classes], np.float64)
        self._cum = np.cumsum(w / w.sum())
        self._lat_mu = np.asarray([c.lat_mu for c in self.classes])
        self._lat_sigma = np.asarray([c.lat_sigma for c in self.classes])
        self._avail = np.asarray([c.avail for c in self.classes])

    def _hash(self, ids: np.ndarray, salt: int,
              nonce: int = 0) -> np.ndarray:
        ids = np.asarray(ids, np.uint64)
        with np.errstate(over="ignore"):
            h = _mix(ids + _mix(self.seed ^ np.uint64(salt)))
        if nonce:
            h = _mix(h ^ _mix(np.uint64(nonce)))
        return h

    def device_class(self, ids) -> np.ndarray:
        """(k,) class index per client — fixed for the client's lifetime."""
        u = _u01(self._hash(ids, _SALT_CLASS))
        return np.searchsorted(self._cum, u, side="right").clip(
            0, len(self.classes) - 1)

    def latency(self, ids, nonce: int = 0) -> np.ndarray:
        """(k,) lognormal dispatch->arrival latencies, deterministic in
        (population seed, client id, nonce) — use the dispatch index as the
        nonce so re-dispatching a client redraws its latency."""
        c = self.device_class(ids)
        u1 = _u01(self._hash(ids, _SALT_LAT_A, nonce))
        u2 = _u01(self._hash(ids, _SALT_LAT_B, nonce))
        z = np.sqrt(-2.0 * np.log(np.maximum(u1, 1e-12))) \
            * np.cos(2.0 * np.pi * u2)
        return np.exp(self._lat_mu[c] + self._lat_sigma[c] * z)

    def available(self, ids, t: float) -> np.ndarray:
        """(k,) bool availability at simulated time t: the class base rate
        modulated by a per-client diurnal phase (period ``day``), resampled
        per ~1-simulated-second bucket."""
        ids = np.asarray(ids, np.uint64)
        phase = _u01(self._hash(ids, _SALT_PHASE)) * 2.0 * np.pi
        c = self.device_class(ids)
        p = self._avail[c] * (0.75 + 0.25 * np.sin(
            2.0 * np.pi * t / self.day + phase))
        u = _u01(self._hash(ids, _SALT_AVAIL, nonce=int(t) + 1))
        return u < p

    def sample_cohort(self, k: int, t: float, nonce: int = 0,
                      tries: int = 8) -> np.ndarray:
        """Up to k distinct available client ids at simulated time t,
        deterministic in (seed, t-bucket, nonce).  May return fewer than k
        (or none) when availability is low — the async engine retries later
        in simulated time."""
        rng = np.random.default_rng(
            [int(self.seed), int(nonce), int(t) + 1])
        picked: list = []
        seen: set = set()
        for _ in range(tries):
            if len(picked) >= k:
                break
            cand = rng.integers(0, self.n_clients, size=max(4 * k, 16))
            ok = self.available(cand, t)
            for cid in cand[ok]:
                if int(cid) not in seen:
                    seen.add(int(cid))
                    picked.append(int(cid))
                    if len(picked) >= k:
                        break
        return np.asarray(picked[:k], np.int64)
