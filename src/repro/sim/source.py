"""Client-arrival sources feeding the async round engine.

The engine (``repro.core.async_round.run_async``) pulls work through one
callable interface::

    source(dispatch_idx, sim_time, k) -> None | (specs, batches, latencies)

returning at most ``k`` clients ready to be dispatched now: their
``ClientSpec``s, the client-stacked local batches (leading axis = the
returned cohort size, same pytree layout as ``launch.train``'s per-round
batches) and per-client simulated latencies (dispatch -> update arrival).
``None`` (or an empty draw) means nobody is available; the engine advances
simulated time and retries.

Three implementations:

  * ``ParitySource`` — the parity anchor: dispatch d hands over *exactly*
    ``data_fn(d)``'s full cohort with constant latency, so every merge
    consumes a complete fresh cohort and the engine provably degenerates to
    ``run_rounds`` (bit-equal, see ``tests/test_async_round.py``).
  * ``TraceSource`` — a deterministic infinite client stream (data_fn
    cohorts unrolled client-by-client) with scripted per-client latencies;
    what the differential-oracle and staleness tests drive.
  * ``PopulationSource`` — the production shape: cohorts sampled from a
    ``ClientPopulation`` availability trace, latencies drawn per dispatch
    from the client's device class.
"""
from __future__ import annotations

from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

from repro.sim.population import ClientPopulation


class ParitySource:
    """Full-cohort deterministic arrivals (the async engine's parity mode).

    Requires the whole pool free (``k >=`` the cohort size) before handing
    out the next cohort — partial dispatch would break round-for-round
    equivalence with ``run_rounds``.
    """

    def __init__(self, data_fn: Callable[[int], Tuple], latency: float = 1.0):
        self.data_fn = data_fn
        self.latency = float(latency)

    def __call__(self, d: int, t: float, k: int):
        specs, batches = self.data_fn(d)
        if k < len(specs):
            return None                     # wait for the pool to drain
        return specs, batches, np.full(len(specs), self.latency)


class TraceSource:
    """Deterministic client stream with scripted latencies.

    ``data_fn`` cohorts are unrolled into an infinite per-client queue;
    each call hands the engine the next ``k`` clients with
    ``latency_fn(i)`` (i = global client index in the stream).  Use a
    skewed ``latency_fn`` to script stragglers and force partial,
    staleness-bearing merges.
    """

    def __init__(self, data_fn: Callable[[int], Tuple],
                 latency_fn: Callable[[int], float]):
        self.data_fn = data_fn
        self.latency_fn = latency_fn
        self._queue: List[Tuple] = []       # (spec, per-client batch tree)
        self._next_cohort = 0
        self._next_client = 0

    def _refill(self, k: int) -> None:
        import jax
        while len(self._queue) < k:
            specs, batches = self.data_fn(self._next_cohort)
            self._next_cohort += 1
            for i, s in enumerate(specs):
                self._queue.append(
                    (s, jax.tree.map(lambda a, i=i: a[i], batches)))

    def __call__(self, d: int, t: float, k: int):
        import jax
        import jax.numpy as jnp
        self._refill(k)
        take, self._queue = self._queue[:k], self._queue[k:]
        specs = [s for s, _ in take]
        batches = jax.tree.map(lambda *xs: jnp.stack(xs),
                               *[b for _, b in take])
        lat = np.asarray([self.latency_fn(self._next_client + i)
                          for i in range(len(take))], np.float64)
        self._next_client += len(take)
        return specs, batches, lat


class PopulationSource:
    """Arrivals sampled from a ``ClientPopulation`` availability trace.

    ``spec_fn(ids) -> [ClientSpec]`` maps sampled client ids to their
    architectures/data counts (derive from ``population.device_class`` for
    millions of registered clients, or index a prebuilt spec list);
    ``batch_fn(d, ids)`` synthesizes the stacked local batches for one
    dispatch.  Latencies are drawn per (client, dispatch) from the device
    class — deterministic, so a run is a replayable trace.
    """

    def __init__(self, population: ClientPopulation,
                 spec_fn: Callable[[np.ndarray], Sequence],
                 batch_fn: Callable[[int, np.ndarray], object]):
        self.population = population
        self.spec_fn = spec_fn
        self.batch_fn = batch_fn

    def __call__(self, d: int, t: float, k: int):
        ids = self.population.sample_cohort(k, t, nonce=d)
        if ids.size == 0:
            return None
        lat = self.population.latency(ids, nonce=d)
        return list(self.spec_fn(ids)), self.batch_fn(d, ids), lat


def make_class_spec_fn(cfg, population: ClientPopulation,
                       n_data_range: Tuple[int, int] = (100, 250),
                       malicious_frac: float = 0.0):
    """Spec factory tying architecture width to the device class (slow
    mobile tiers train thin models — the HeteroFL-style skew): returns
    ``spec_fn(ids)`` for ``PopulationSource`` that derives each client's
    ``ClientSpec`` from its hashed class, n_data (inclusive range) and an
    id-hashed malicious flag, without materializing the population."""
    from repro.core.server import ClientSpec
    from repro.models.masks import ClientArch, full_client,  \
        max_section_depths
    maxd = max_section_depths(cfg)
    archs = {c.width_mult: ClientArch(c.width_mult, maxd)
             for c in population.classes}

    def spec_fn(ids: np.ndarray):
        from repro.sim.population import _u01
        cls = population.device_class(ids)
        u = _u01(population._hash(np.asarray(ids), 0x5bd1e995))
        lo, hi = n_data_range
        nd = (lo + np.floor(u * (hi - lo + 1))).astype(np.int64).clip(lo, hi)
        mal = _u01(population._hash(np.asarray(ids), 0x2545f491)) \
            < malicious_frac
        return [ClientSpec(
            arch=full_client(cfg) if mal[i]       # attackers go full-size
            else archs[population.classes[cls[i]].width_mult],
            n_data=int(nd[i]), malicious=bool(mal[i]))
            for i in range(len(ids))]
    return spec_fn
