"""Trace-driven client-population simulation for the async FL engine."""
from repro.sim.population import (DEFAULT_CLASSES, ClientPopulation,
                                  DeviceClass)
from repro.sim.source import (ParitySource, PopulationSource, TraceSource,
                              make_class_spec_fn)

__all__ = ["ClientPopulation", "DeviceClass", "DEFAULT_CLASSES",
           "ParitySource", "TraceSource", "PopulationSource",
           "make_class_spec_fn"]
