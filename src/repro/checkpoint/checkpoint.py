"""Self-contained pytree checkpointing (npz payload + json structure)."""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Tuple

import jax
import numpy as np
from jax.tree_util import tree_flatten_with_path, keystr


def _pathstr(path) -> str:
    return keystr(path)


def save(path: str, tree: Any, meta: Dict[str, Any] | None = None) -> None:
    flat = tree_flatten_with_path(tree)[0]
    names = [_pathstr(p) for p, _ in flat]
    arrays = {f"a{i}": np.asarray(l) for i, (_, l) in enumerate(flat)}
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    np.savez(path + ".npz", **arrays)
    with open(path + ".json", "w") as f:
        json.dump({"names": names, "meta": meta or {}}, f)


def save_from_buffer(path: str, index, buf, meta: Dict[str, Any] | None = None) -> None:
    """Checkpoint a resident flat buffer (see ``repro.core.round``).

    The (N,) f32 buffer is unflattened back to the original leaf dtypes only
    here, at the eval/checkpoint boundary — the training loop itself never
    leaves flat space.  A model-sharded buffer (global P("model") layout,
    ``repro.sharding.cohort.global_sharding``) is explicitly gathered to
    host first — the one place the full global model is materialized.
    ``index`` is the ``flat.FlatIndex`` the buffer was packed with;
    checkpoints written this way are byte-compatible with
    ``save``/``restore`` on the equivalent pytree (the inert pad tail, if
    any, is dropped by the unflatten).
    """
    from repro.core import flat
    if isinstance(buf, jax.Array):
        buf = np.asarray(jax.device_get(buf))    # gathers sharded buffers
    save(path, flat.unflatten(index, buf),
         meta=dict(meta or {}, flat_n=int(index.n)))


def restore_to_buffer(path: str, like: Any,
                      mesh=None) -> Tuple[Any, Any, Dict[str, Any]]:
    """Restore a checkpoint straight onto the resident flat representation:
    returns (FlatIndex, (N,) f32 buffer, meta) ready for ``run_rounds``.

    With ``mesh`` set, the index pads N with ``sharding.cohort.pad_unit``
    (model shards x quantile column tile — the same width ``run_rounds``
    builds itself) and the buffer is ``device_put`` onto the sharded
    P("model") global layout, so the first resident round starts from
    N/n_model slices per device with no reshard copy.
    """
    from repro.core import flat
    from repro.sharding import cohort as cohort_sh
    tree, meta = restore(path, like)
    index = flat.get_index(tree, pad_to=cohort_sh.pad_unit(mesh))
    buf = flat.flatten(index, tree)
    if mesh is not None:
        buf = jax.device_put(buf, cohort_sh.global_sharding(mesh))
    return index, buf, meta


def restore(path: str, like: Any) -> Tuple[Any, Dict[str, Any]]:
    """Restore into the structure of ``like`` (shape/dtype-checked)."""
    with open(path + ".json") as f:
        spec = json.load(f)
    data = np.load(path + ".npz")
    flat = tree_flatten_with_path(like)[0]
    names = [_pathstr(p) for p, _ in flat]
    # hard errors, not asserts: a mismatched restore under ``python -O``
    # must not silently load the wrong parameters
    if names != spec["names"]:
        bad = next((f"{a!r} != {b!r}" for a, b in zip(names, spec["names"])
                    if a != b),
                   f"{len(names)} leaves in tree vs "
                   f"{len(spec['names'])} in checkpoint")
        raise ValueError(f"checkpoint/tree structure mismatch at {bad} "
                         f"(restoring {path!r})")
    leaves = []
    for i, (_, l) in enumerate(flat):
        a = data[f"a{i}"]
        if tuple(a.shape) != tuple(np.shape(l)):
            raise ValueError(
                f"checkpoint shape mismatch at {names[i]}: checkpoint has "
                f"{tuple(a.shape)}, tree expects {tuple(np.shape(l))} "
                f"(restoring {path!r})")
        leaves.append(jax.numpy.asarray(a, dtype=l.dtype))
    treedef = jax.tree.structure(like)
    return jax.tree.unflatten(treedef, leaves), spec["meta"]
