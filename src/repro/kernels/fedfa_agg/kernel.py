"""FedFA server-aggregation Pallas kernels.

Two hot-spot reductions from Alg. 1 that at 480B-parameter global-model
scale dominate the server step:

  * ``trimmed_sumsq`` — Σ w² over entries with |w| <= t (the 95th-percentile
    trimmed norm of §4.3).  Grid-strided reduction; the running partial sum
    lives in a VMEM scratch accumulated across grid steps.
  * ``scaled_accum``  — M'[n] += Σ_c (N_c·α_c) · w_c[n] · mask[n]
    (Alg. 1 line 19 fused over the client axis: one pass over HBM instead
    of m passes).

Both operate on 2D-flattened leaves; ops.py handles pytree plumbing.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _trimmed_sumsq_kernel(w_ref, t_ref, o_ref, acc, *, nb: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _():
        acc[...] = jnp.zeros_like(acc)

    w = w_ref[...].astype(jnp.float32)
    t = t_ref[0, 0]
    keep = jnp.abs(w) <= t
    acc[...] += jnp.sum(jnp.where(keep, w * w, 0.0), axis=0, keepdims=True)

    @pl.when(i == nb - 1)
    def _done():
        o_ref[0, 0] = jnp.sum(acc[...])


def trimmed_sumsq(w: jax.Array, thresh: jax.Array, *, block: int = 2048,
                  interpret: bool = False) -> jax.Array:
    """w: (n, lanes) 2D; thresh scalar. Returns scalar fp32 Σ w²·[|w|<=t]."""
    n, lanes = w.shape
    assert n % block == 0
    nb = n // block
    t2 = thresh.reshape(1, 1).astype(jnp.float32)
    kernel = functools.partial(_trimmed_sumsq_kernel, nb=nb)
    out = pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((block, lanes), lambda i: (i, 0)),
                  pl.BlockSpec((1, 1), lambda i: (0, 0),
                               memory_space=pltpu.SMEM)],
        out_specs=pl.BlockSpec((1, 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.float32),
        scratch_shapes=[pltpu.VMEM((1, lanes), jnp.float32)],
        interpret=interpret,
    )(w, t2)
    return out[0, 0]


def _scaled_accum_kernel(x_ref, w_ref, mask_ref, o_ref, *, m: int):
    x = x_ref[...].astype(jnp.float32)               # (m, block)
    wts = w_ref[...].astype(jnp.float32)             # (m, 1)
    msk = mask_ref[...].astype(jnp.float32)          # (1, block)
    o_ref[...] = (jnp.sum(x * wts, axis=0, keepdims=True) * msk)


def scaled_accum(x: jax.Array, weights: jax.Array, mask: jax.Array, *,
                 block: int = 4096, interpret: bool = False) -> jax.Array:
    """x: (m, n); weights: (m,) = N_c·α_c; mask: (n,). Returns (n,) fp32."""
    m, n = x.shape
    assert n % block == 0
    nb = n // block
    w2 = weights.reshape(m, 1).astype(jnp.float32)
    m2 = mask.reshape(1, n).astype(jnp.float32)
    kernel = functools.partial(_scaled_accum_kernel, m=m)
    out = pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((m, block), lambda i: (0, i)),
                  pl.BlockSpec((m, 1), lambda i: (0, 0)),
                  pl.BlockSpec((1, block), lambda i: (0, i))],
        out_specs=pl.BlockSpec((1, block), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, n), jnp.float32),
        interpret=interpret,
    )(x, w2, m2)
    return out[0]


def _quant_accum_kernel(x_ref, w_ref, seg_ref, mask_ref, o_ref):
    """Fused dequantize-accumulate: o[n] = Σ_c x[c,n]·w[c, seg[n]]·mask[n].

    ``x`` arrives in the admitted dtype (int8/bf16) and is upcast in VMEM
    only; ``w`` is the (m, S) per-(client, segment) weight table with the
    dequant scales (and α, depth gates, N_c) already folded in, gathered
    per column through a segment one-hot matmul — so no f32 copy of the
    quantized rows ever reaches HBM.  Pad columns carry seg = -1, which
    zeroes their one-hot row and hence their contribution.
    """
    x = x_ref[...].astype(jnp.float32)                       # (m, block)
    seg = seg_ref[...]                                       # (1, block) i32
    blk = x.shape[1]
    _, S = w_ref.shape
    oh = (seg[0][:, None] == jax.lax.broadcasted_iota(
        jnp.int32, (blk, S), 1)).astype(jnp.float32)         # (block, S)
    wcol = jax.lax.dot_general(
        w_ref[...].astype(jnp.float32), oh,
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)                  # (m, block)
    o_ref[...] = jnp.sum(x * wcol, axis=0, keepdims=True) \
        * mask_ref[...].astype(jnp.float32)


def quant_accum(x: jax.Array, wtab: jax.Array, seg: jax.Array,
                mask: jax.Array, *, block: int = 4096,
                interpret: bool = False) -> jax.Array:
    """x: (m, n) quantized rows; wtab: (m, S) f32 per-(client, segment)
    weights (dequant scales folded in); seg: (n,) int32 segment ids (-1 on
    inert pads); mask: (n,).  Returns (n,) fp32."""
    m, n = x.shape
    assert n % block == 0
    nb = n // block
    out = pl.pallas_call(
        _quant_accum_kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((m, block), lambda i: (0, i)),
                  pl.BlockSpec((m, wtab.shape[1]), lambda i: (0, 0)),
                  pl.BlockSpec((1, block), lambda i: (0, i)),
                  pl.BlockSpec((1, block), lambda i: (0, i))],
        out_specs=pl.BlockSpec((1, block), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, n), jnp.float32),
        interpret=interpret,
    )(x, wtab.astype(jnp.float32), seg.reshape(1, n),
      mask.reshape(1, n).astype(jnp.float32))
    return out[0]
