"""jit'd wrappers for the FedFA aggregation kernels (padding + dispatch)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.fedfa_agg import ref
from repro.kernels.fedfa_agg.kernel import scaled_accum, trimmed_sumsq


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("use_kernel", "interpret"))
def trimmed_norm(w_flat: jax.Array, thresh: jax.Array, *,
                 use_kernel=None, interpret=False) -> jax.Array:
    """sqrt(Σ w²·[|w|<=t]) over a flat vector, any length (zero-padded)."""
    if use_kernel is None:
        use_kernel = _on_tpu()
    if not (use_kernel or interpret):
        return jnp.sqrt(ref.trimmed_sumsq_ref(w_flat, thresh))
    lanes = 128
    n = w_flat.size
    padded = ((n + lanes - 1) // lanes) * lanes
    rows = padded // lanes
    block = min(2048, rows)
    rows_p = ((rows + block - 1) // block) * block
    w2 = jnp.zeros((rows_p * lanes,), w_flat.dtype).at[:n].set(w_flat)
    # padding zeros pass |0|<=t -> contribute 0 to the sum: safe.
    ss = trimmed_sumsq(w2.reshape(rows_p, lanes), thresh, block=block,
                       interpret=interpret or not _on_tpu())
    return jnp.sqrt(ss)


@functools.partial(jax.jit, static_argnames=("use_kernel", "interpret"))
def accumulate(x: jax.Array, weights: jax.Array, mask: jax.Array, *,
               use_kernel=None, interpret=False) -> jax.Array:
    """Fused Σ_c weights[c]·x[c]·mask over the client axis. x: (m, n)."""
    if use_kernel is None:
        use_kernel = _on_tpu()
    if not (use_kernel or interpret):
        return ref.scaled_accum_ref(x, weights, mask)
    m, n = x.shape
    block = 4096 if n >= 4096 else max(128, 1 << (n - 1).bit_length())
    pad = (-n) % block
    xp = jnp.pad(x, ((0, 0), (0, pad)))
    mp = jnp.pad(mask, (0, pad))
    out = scaled_accum(xp, weights, mp, block=block,
                       interpret=interpret or not _on_tpu())
    return out[:n]
