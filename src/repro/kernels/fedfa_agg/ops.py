"""jit'd wrappers for the FedFA aggregation kernels (padding + dispatch)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.kernels.fedfa_agg import ref
from repro.kernels.fedfa_agg.kernel import (quant_accum, scaled_accum,
                                            trimmed_sumsq)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("use_kernel", "interpret"))
def trimmed_norm(w_flat: jax.Array, thresh: jax.Array, *,
                 use_kernel=None, interpret=False) -> jax.Array:
    """sqrt(Σ w²·[|w|<=t]) over a flat vector, any length (zero-padded)."""
    if use_kernel is None:
        use_kernel = _on_tpu()
    if not (use_kernel or interpret):
        return jnp.sqrt(ref.trimmed_sumsq_ref(w_flat, thresh))
    lanes = 128
    n = w_flat.size
    padded = ((n + lanes - 1) // lanes) * lanes
    rows = padded // lanes
    block = min(2048, rows)
    rows_p = ((rows + block - 1) // block) * block
    w2 = jnp.zeros((rows_p * lanes,), w_flat.dtype).at[:n].set(w_flat)
    # padding zeros pass |0|<=t -> contribute 0 to the sum: safe.
    ss = trimmed_sumsq(w2.reshape(rows_p, lanes), thresh, block=block,
                       interpret=interpret or not _on_tpu())
    return jnp.sqrt(ss)


def _accum_local(x: jax.Array, weights: jax.Array, mask: jax.Array,
                 use_kernel: bool, interpret: bool) -> jax.Array:
    """The unsharded accumulate body: Σ_c weights[c]·x[c]·mask on whatever
    slice of the client axis this device holds."""
    if not (use_kernel or interpret):
        return ref.scaled_accum_ref(x, weights, mask)
    m, n = x.shape
    block = 4096 if n >= 4096 else max(128, 1 << (n - 1).bit_length())
    pad = (-n) % block
    xp = jnp.pad(x, ((0, 0), (0, pad)))
    mp = jnp.pad(mask, (0, pad))
    out = scaled_accum(xp, weights, mp, block=block,
                       interpret=interpret or not _on_tpu())
    return out[:n]


def _quant_accum_local(x: jax.Array, weights: jax.Array, wtab: jax.Array,
                       seg: jax.Array, mask: jax.Array,
                       use_kernel: bool, interpret: bool) -> jax.Array:
    """Unsharded fused dequantize-accumulate body: the per-client weight
    folds into the (m, S) table before the kernel, so the quantized rows
    are consumed by exactly one pass."""
    wt = wtab.astype(jnp.float32) * weights.astype(jnp.float32)[:, None]
    if not (use_kernel or interpret):
        return ref.quant_accum_ref(x, wt, seg, mask)
    m, n = x.shape
    block = 4096 if n >= 4096 else max(128, 1 << (n - 1).bit_length())
    pad = (-n) % block
    xp = jnp.pad(x, ((0, 0), (0, pad)))
    sp = jnp.pad(seg, (0, pad), constant_values=-1)
    mp = jnp.pad(mask, (0, pad))
    out = quant_accum(xp, wt, sp, mp, block=block,
                      interpret=interpret or not _on_tpu())
    return out[:n]


def accumulate_contract(n_padded: int, mesh=None, rows=None, segs=None):
    """Declared contract of the aggregation path built on ``accumulate``
    (``flat.aggregate_buffers`` lowered standalone on the round's own
    shardings — see ``repro.analysis.contracts``).

    Zero all-gathers, always: the (M', γ) reduction is a per-shard partial
    sum, never a replicated (m, n) re-gather.  On a multi-device data-only
    mesh the partial sums combine as 1-2 psums of exactly ``n_padded``
    elements and no all-reduce exceeds that.  With model shards the
    reductions consume the 2-D P("data", "model") cohort slices directly —
    the N axis is pre-split, so there is NO reduce-scatter: the partial
    sums finish with N-scale all-reduces of exactly ``n_padded / n_model``
    elements over ``data``, plus the distributed trimmed-quantile's
    histogram-plane psums over ``model`` (bounded via ``segs``, the
    segment count — histogram-sized, independent of N).

    With ``rows`` (the padded cohort row count) the contract also budgets
    the statically estimated per-device peak at ``(6 + 12*r) * N * 4``
    bytes, r = rows per data shard — the cohort shard plus the grafting /
    trimmed-norm / partial-sum intermediates (measured ~11-15 N-multiples
    on the canonical fixture; a replicated cohort blows it).
    """
    from repro.analysis.contracts import Contract
    from repro.kernels.fedfa_quantile.multilevel import histogram_elems
    from repro.sharding.cohort import data_shards, model_shards
    multi = mesh is not None and mesh.size > 1
    ms = model_shards(mesh)
    peak = {}
    r = max(1, (rows or 1) // data_shards(mesh))
    if rows is not None:
        peak = dict(
            peak_live_bytes_per_device=(None, (6 + 12 * r) * n_padded * 4))
    if not multi:
        return Contract(name="agg/1dev",
                        description="aggregation path, single device",
                        all_gathers=0, **peak)
    scale = n_padded // ms
    cap = scale
    if ms > 1:
        kw = dict(reduce_scatters=0)
        if segs is not None:
            cap = max(scale, histogram_elems(r, segs))
    else:
        kw = {}
    kw.update(allreduce_max_elems=cap, scale_allreduces=(1, 2),
              scale_elems=scale)
    return Contract(
        name=f"agg/ms{ms}",
        description="aggregation path: partial sums, no cohort re-gather",
        all_gathers=0, **kw, **peak)


@functools.partial(jax.jit,
                   static_argnames=("use_kernel", "interpret", "mesh",
                                    "cohort_2d"))
def accumulate(x: jax.Array, weights: jax.Array, mask: jax.Array, *,
               use_kernel=None, interpret=False, mesh=None,
               cohort_2d: bool = False) -> jax.Array:
    """Fused Σ_c weights[c]·x[c]·mask over the client axis. x: (m, n).

    With ``mesh`` set (and the client axis laid out over its ``data`` axis,
    see ``repro.sharding.cohort``), the reduction is expressed with
    ``shard_map``: each device reduces its own client shard — through the
    Pallas kernel on TPU — so the lowering never materializes a replicated
    (m, n) gather.  On a data-only mesh a single n-sized ``psum`` combines
    the partial sums (output replicated).

    ``cohort_2d=True`` declares x already lives in the resident
    P("data", "model") layout (the distributed-quantile norms pass keeps it
    there): each device reduces its own (m/D, n/n_model) slice and ONE
    n/n_model-sized ``psum`` over ``data`` finishes the sum — no
    reduce-scatter, no re-layout.  Otherwise, with model shards (and n
    divisible by them) the model-replicated reduction **reduce-scatters**:
    the model peers of each data shard split that shard's client rows
    between them (zeroing the other peers' weights — exact, any row
    count), a ``psum_scatter`` over ``model`` sums the partials while
    scattering the n axis, and the finishing ``psum`` over ``data`` moves
    only n/n_model elements per device.  Either way the output is sharded
    P("model") — exactly the resident global-buffer layout, so the
    caller's (M'/Γ, γ = 0) merge stays shard-local.
    """
    from repro.sharding.cohort import (DATA_AXIS, MODEL_AXIS, model_shards,
                                       shardable)
    if use_kernel is None:
        use_kernel = _on_tpu()
    if not shardable(mesh, x.shape[0]):
        return _accum_local(x, weights, mask, use_kernel, interpret)
    mo = model_shards(mesh)
    if x.shape[1] % mo != 0:     # non-divisible n: data-only reduction
        mo = 1

    if cohort_2d and mo > 1:
        def _shard2(xs, ws, msk):
            part = _accum_local(xs, ws, msk, use_kernel, interpret)
            return jax.lax.psum(part, DATA_AXIS)

        return shard_map(_shard2, mesh=mesh,
                         in_specs=(P(DATA_AXIS, MODEL_AXIS), P(DATA_AXIS),
                                   P(MODEL_AXIS)),
                         out_specs=P(MODEL_AXIS), check_rep=False)(
                             x, weights, mask)

    def _shard(xs, ws, ms):
        if mo > 1:
            slot = (jnp.arange(xs.shape[0]) * mo) // xs.shape[0]
            ws = jnp.where(slot == jax.lax.axis_index(MODEL_AXIS), ws, 0.0)
        part = _accum_local(xs, ws, ms, use_kernel, interpret)
        if mo > 1:
            part = jax.lax.psum_scatter(part, MODEL_AXIS,
                                        scatter_dimension=0, tiled=True)
        return jax.lax.psum(part, DATA_AXIS)

    out_spec = P(MODEL_AXIS) if mo > 1 else P(None)
    return shard_map(_shard, mesh=mesh,
                     in_specs=(P(DATA_AXIS, None), P(DATA_AXIS), P(None)),
                     out_specs=out_spec, check_rep=False)(x, weights, mask)


@functools.partial(jax.jit,
                   static_argnames=("use_kernel", "interpret", "mesh",
                                    "cohort_2d"))
def accumulate_quant(x: jax.Array, weights: jax.Array, wtab: jax.Array,
                     seg: jax.Array, mask: jax.Array, *,
                     use_kernel=None, interpret=False, mesh=None,
                     cohort_2d: bool = False) -> jax.Array:
    """Fused dequantize + Σ_c weights[c]·wtab[c, seg[n]]·x[c, n]·mask[n].

    The quantized counterpart of ``accumulate``: ``x`` stays in its
    admission dtype (int8/bf16) end to end — dequant scales (times α and
    depth gates) enter through the per-(client, segment) table ``wtab``
    and are gathered per column inside the kernel, so the rows keep the
    read-once property and no (m, n) f32 dequant transient is ever
    materialized.  ``seg`` is the static per-column segment-id row ((n,)
    int32, -1 on the inert pad tail — those columns contribute zero).

    Sharding mirrors ``accumulate`` exactly: data-shard partial sums
    finished by one n-sized psum; ``cohort_2d`` consumes P("data",
    "model") slices with an n/n_model psum over ``data``; otherwise model
    peers split client rows and psum_scatter over ``model``.  Output is
    P("model") with model shards, replicated without.
    """
    from repro.sharding.cohort import (DATA_AXIS, MODEL_AXIS, model_shards,
                                       shardable)
    if use_kernel is None:
        use_kernel = _on_tpu()
    if not shardable(mesh, x.shape[0]):
        return _quant_accum_local(x, weights, wtab, seg, mask,
                                  use_kernel, interpret)
    mo = model_shards(mesh)
    if x.shape[1] % mo != 0:     # non-divisible n: data-only reduction
        mo = 1
    seg2 = seg.reshape(1, -1)

    if cohort_2d and mo > 1:
        def _shard2(xs, ws, wt, sg, msk):
            part = _quant_accum_local(xs, ws, wt, sg[0], msk,
                                      use_kernel, interpret)
            return jax.lax.psum(part, DATA_AXIS)

        return shard_map(_shard2, mesh=mesh,
                         in_specs=(P(DATA_AXIS, MODEL_AXIS), P(DATA_AXIS),
                                   P(DATA_AXIS, None), P(None, MODEL_AXIS),
                                   P(MODEL_AXIS)),
                         out_specs=P(MODEL_AXIS), check_rep=False)(
                             x, weights, wtab, seg2, mask)

    def _shard(xs, ws, wt, sg, msk):
        if mo > 1:
            slot = (jnp.arange(xs.shape[0]) * mo) // xs.shape[0]
            ws = jnp.where(slot == jax.lax.axis_index(MODEL_AXIS), ws, 0.0)
        part = _quant_accum_local(xs, ws, wt, sg[0], msk,
                                  use_kernel, interpret)
        if mo > 1:
            part = jax.lax.psum_scatter(part, MODEL_AXIS,
                                        scatter_dimension=0, tiled=True)
        return jax.lax.psum(part, DATA_AXIS)

    out_spec = P(MODEL_AXIS) if mo > 1 else P(None)
    return shard_map(_shard, mesh=mesh,
                     in_specs=(P(DATA_AXIS, None), P(DATA_AXIS),
                               P(DATA_AXIS, None), P(None, None), P(None)),
                     out_specs=out_spec, check_rep=False)(
                         x, weights, wtab, seg2, mask)
