"""Pure-jnp oracles for the FedFA aggregation kernels."""
from __future__ import annotations

import jax.numpy as jnp


def trimmed_sumsq_ref(w, thresh):
    wf = w.astype(jnp.float32)
    return jnp.sum(jnp.where(jnp.abs(wf) <= thresh, wf * wf, 0.0))


def scaled_accum_ref(x, weights, mask):
    xf = x.astype(jnp.float32)
    return jnp.einsum("mn,m->n", xf, weights.astype(jnp.float32)) \
        * mask.astype(jnp.float32)
