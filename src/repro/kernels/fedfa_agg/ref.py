"""Pure-jnp oracles for the FedFA aggregation kernels."""
from __future__ import annotations

import jax.numpy as jnp


def trimmed_sumsq_ref(w, thresh):
    wf = w.astype(jnp.float32)
    return jnp.sum(jnp.where(jnp.abs(wf) <= thresh, wf * wf, 0.0))


def scaled_accum_ref(x, weights, mask):
    xf = x.astype(jnp.float32)
    return jnp.einsum("mn,m->n", xf, weights.astype(jnp.float32)) \
        * mask.astype(jnp.float32)


def quant_accum_ref(x, wtab, seg, mask):
    """Σ_c x[c,n]·wtab[c, seg[n]]·mask[n]; seg = -1 columns contribute 0."""
    valid = (seg >= 0).astype(jnp.float32)
    w = jnp.take(wtab.astype(jnp.float32),
                 jnp.clip(seg, 0, wtab.shape[1] - 1), axis=1) * valid[None, :]
    return jnp.sum(x.astype(jnp.float32) * w, axis=0) \
        * mask.astype(jnp.float32)
