"""jit'd wrapper: pads to tile/lane boundaries, dispatches kernel vs oracle.

On TPU the Pallas kernel is the default; elsewhere (this CPU container) the
oracle runs and the kernel is exercised in interpret mode by tests.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import ref
from repro.kernels.flash_attention.kernel import flash_attention


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x: jax.Array, axis: int, mult: int):
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x, n
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), n


@functools.partial(jax.jit, static_argnames=("causal", "window", "bq", "bk",
                                             "use_kernel", "interpret"))
def attention(q, k, v, *, causal: bool = True, window: Optional[int] = None,
              bq: int = 128, bk: int = 128,
              use_kernel: Optional[bool] = None,
              interpret: bool = False) -> jax.Array:
    """Public entry point; q (B,Sq,H,hd), k/v (B,Sk,K,hd)."""
    if use_kernel is None:
        use_kernel = _on_tpu()
    if not use_kernel and not interpret:
        return ref.attention_ref(q, k, v, causal=causal, window=window)
    B, Sq, H, hd = q.shape
    bq = min(bq, max(8, 1 << (Sq - 1).bit_length()))
    bk = min(bk, max(8, 1 << (k.shape[1] - 1).bit_length()))
    qp, Sq0 = _pad_to(q, 1, bq)
    kp, Sk0 = _pad_to(k, 1, bk)
    vp, _ = _pad_to(v, 1, bk)
    # pad head_dim to the 128-lane boundary for the MXU
    qp, hd0 = _pad_to(qp, 3, 128)
    kp, _ = _pad_to(kp, 3, 128)
    vp, _ = _pad_to(vp, 3, 128)
    out = flash_attention(qp, kp, vp, causal=causal, window=window,
                          bq=bq, bk=bk, kv_len=Sk0, scale=hd0 ** -0.5,
                          interpret=interpret)
    return out[:, :Sq0, :, :hd0]
