"""Pure-jnp oracle for the flash-attention kernel (same contract)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -2.0 ** 30


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True,
                  window: Optional[int] = None) -> jax.Array:
    """q: (B, Sq, H, hd); k, v: (B, Sk, K, hd). fp32 softmax, GQA expand."""
    B, Sq, H, hd = q.shape
    Sk, K = k.shape[1], k.shape[2]
    rep = H // K
    k = jnp.repeat(k, rep, axis=2)
    v = jnp.repeat(v, rep, axis=2)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * hd ** -0.5
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v.astype(jnp.float32)).astype(q.dtype)
