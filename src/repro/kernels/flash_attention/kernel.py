"""Blockwise (flash) causal GQA attention as a Pallas TPU kernel.

Design for the TPU memory hierarchy:
  * grid = (batch, q_heads, Sq/bq, Sk/bk); the innermost kv axis revisits the
    same output block, carrying the online-softmax state (running max m,
    denominator l, accumulator acc) in VMEM scratch across iterations.
  * BlockSpec tiles: q (1, bq, 1, hd), k/v (1, bk, 1, hd) — hd is padded to a
    multiple of 128 by the wrapper so the MXU matmuls are lane-aligned.
  * GQA is expressed in the kv index_map (kv_head = q_head // group), so no
    repeated-KV materialization ever reaches HBM.
  * causal/window masking is applied in-kernel; fully-masked kv blocks are
    cheap (masked to -inf, no branch divergence on TPU).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0 ** 30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                 scale: float, bq: int, bk: int, causal: bool,
                 window: Optional[int], nk: int, kv_len: int):
    j = pl.program_id(3)
    i = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, :, 0, :].astype(jnp.float32) * scale        # (bq, hd)
    k = k_ref[0, :, 0, :].astype(jnp.float32)                # (bk, hd)
    v = v_ref[0, :, 0, :].astype(jnp.float32)

    s = q @ k.T                                              # (bq, bk) MXU
    qpos = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = kpos < kv_len                # exclude tile padding
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                                      # (bq, 1)
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.where(mask, jnp.exp(s - m_new), 0.0)             # (bq, bk)
    corr = jnp.exp(m_prev - m_new)                           # (bq, 1)
    l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * corr + p @ v
    m_scr[...] = m_new

    @pl.when(j == nk - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, :, 0, :] = (acc_scr[...] / l).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: Optional[int] = None,
                    bq: int = 128, bk: int = 128, kv_len: Optional[int] = None,
                    scale: Optional[float] = None,
                    interpret: bool = False) -> jax.Array:
    """q: (B, Sq, H, hd); k, v: (B, Sk, K, hd) with H % K == 0.

    Shapes must tile: Sq % bq == 0, Sk % bk == 0 (the ops.py wrapper pads).
    ``kv_len``: true kv length before padding (padded slots masked out).
    ``scale``: softmax scale; defaults to hd**-0.5 of the (padded) head dim —
    pass the unpadded value when the wrapper pads hd.
    """
    B, Sq, H, hd = q.shape
    Sk, K = k.shape[1], k.shape[2]
    assert H % K == 0 and Sq % bq == 0 and Sk % bk == 0
    group = H // K
    nq, nk = Sq // bq, Sk // bk
    scale = hd ** -0.5 if scale is None else scale

    kernel = functools.partial(_attn_kernel, scale=scale, bq=bq, bk=bk,
                               causal=causal, window=window, nk=nk,
                               kv_len=kv_len if kv_len is not None else Sk)
    return pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, 1, hd), lambda b, h, i, j: (b, i, h, 0)),
            pl.BlockSpec((1, bk, 1, hd), lambda b, h, i, j: (b, j, h // group, 0)),
            pl.BlockSpec((1, bk, 1, hd), lambda b, h, i, j: (b, j, h // group, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, hd), lambda b, h, i, j: (b, i, h, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
