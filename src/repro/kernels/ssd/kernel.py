"""Mamba-2 SSD intra-chunk Pallas kernel.

TPU decomposition of the SSD algorithm: the *quadratic intra-chunk* term
(C·Bᵀ masked-decay matmul) and the per-chunk state contribution are
matmul-heavy — they run on the MXU inside this kernel — while the cheap
sequential inter-chunk state carry stays in XLA (lax.scan in ops.py).

Per grid point (one chunk × one head):
  la = dt * A;  L = cumsum(la)
  M[t,s] = (C_t·B_s) * exp(L_t - L_s) * dt_s   for s <= t
  y_intra = M @ x                               (Q,hp)
  state   = Σ_s exp(L_Q - L_s)·dt_s · B_s ⊗ x_s (hp,N)
Exports L so ops.py can form y_inter = exp(L_t)·C_t·h0 and the decays.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref,
                y_ref, st_ref, l_ref, *, Q: int):
    x = x_ref[0, :, 0, :].astype(jnp.float32)        # (Q, hp)
    dt = dt_ref[0, :, 0].astype(jnp.float32)         # (Q,)
    A = a_ref[0, 0]                                  # scalar
    B = b_ref[0].astype(jnp.float32)                 # (Q, N)
    C = c_ref[0].astype(jnp.float32)                 # (Q, N)

    la = dt * A                                      # log a_t  (Q,)
    L = jnp.cumsum(la)                               # (Q,)

    CB = C @ B.T                                     # (Q, Q) MXU
    diff = L[:, None] - L[None, :]
    tpos = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    spos = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    causal = spos <= tpos
    M = jnp.where(causal, CB * jnp.exp(jnp.where(causal, diff, 0.0)), 0.0)
    M = M * dt[None, :]
    y_ref[0, :, 0, :] = (M @ x).astype(y_ref.dtype)  # (Q, hp) MXU

    decay_end = jnp.exp(L[-1] - L)                   # (Q,)
    dB = B * (dt * decay_end)[:, None]               # (Q, N)
    st_ref[0, 0] = (x.T @ dB).astype(st_ref.dtype)   # (hp, N) MXU
    l_ref[0, :, 0] = L.astype(l_ref.dtype)


def ssd_intra_chunk(x: jax.Array, dt: jax.Array, A: jax.Array,
                    B: jax.Array, C: jax.Array, *,
                    interpret: bool = False):
    """x: (G, Q, nh, hp); dt: (G, Q, nh); A: (nh,); B, C: (G, Q, N)
    where G = batch * n_chunks.  Returns (y_intra, chunk_state, L):
    (G,Q,nh,hp), (G,nh,hp,N), (G,Q,nh) — all fp32."""
    G, Q, nh, hp = x.shape
    N = B.shape[-1]
    A2 = A.reshape(nh, 1).astype(jnp.float32)
    kernel = functools.partial(_ssd_kernel, Q=Q)
    return pl.pallas_call(
        kernel,
        grid=(G, nh),
        in_specs=[
            pl.BlockSpec((1, Q, 1, hp), lambda g, h: (g, 0, h, 0)),
            pl.BlockSpec((1, Q, 1), lambda g, h: (g, 0, h)),
            pl.BlockSpec((1, 1), lambda g, h: (h, 0)),
            pl.BlockSpec((1, Q, N), lambda g, h: (g, 0, 0)),
            pl.BlockSpec((1, Q, N), lambda g, h: (g, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, Q, 1, hp), lambda g, h: (g, 0, h, 0)),
            pl.BlockSpec((1, 1, hp, N), lambda g, h: (g, h, 0, 0)),
            pl.BlockSpec((1, Q, 1), lambda g, h: (g, 0, h)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((G, Q, nh, hp), jnp.float32),
            jax.ShapeDtypeStruct((G, nh, hp, N), jnp.float32),
            jax.ShapeDtypeStruct((G, Q, nh), jnp.float32),
        ],
        interpret=interpret,
    )(x, dt, A2, B, C)
