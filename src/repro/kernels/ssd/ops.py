"""jit'd SSD wrapper: kernel for intra-chunk, lax.scan for the state carry."""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels.ssd import ref
from repro.kernels.ssd.kernel import ssd_intra_chunk


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("chunk", "use_kernel", "interpret"))
def ssd(x, dt, A, B, C, chunk: int, *, use_kernel=None, interpret=False
        ) -> Tuple[jax.Array, jax.Array]:
    """Full chunked SSD matching repro.models.ssm.ssd_chunked_ref.
    x: (b,S,nh,hp); dt: (b,S,nh); A: (nh,); B,C: (b,S,N).
    Returns (y (b,S,nh,hp), final_state (b,nh,hp,N))."""
    if use_kernel is None:
        use_kernel = _on_tpu()
    b, S, nh, hp = x.shape
    N = B.shape[-1]
    Q = chunk
    pad = (-S) % Q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    Sp = S + pad
    nc = Sp // Q
    xg = x.reshape(b * nc, Q, nh, hp)
    dtg = dt.reshape(b * nc, Q, nh)
    Bg = B.reshape(b * nc, Q, N)
    Cg = C.reshape(b * nc, Q, N)

    if use_kernel or interpret:
        y_intra, state, L = ssd_intra_chunk(xg, dtg, A, Bg, Cg,
                                            interpret=interpret or not _on_tpu())
    else:
        y_intra, state, L = ref.ssd_intra_chunk_ref(xg, dtg, A, Bg, Cg)

    # inter-chunk carry (cheap, sequential): h_{c+1} = decay_c * h_c + state_c
    y_intra = y_intra.reshape(b, nc, Q, nh, hp)
    state = state.reshape(b, nc, nh, hp, N)
    L = L.reshape(b, nc, Q, nh)
    Cc = Cg.reshape(b, nc, Q, N).astype(jnp.float32)
    chunk_decay = jnp.exp(L[:, :, -1, :])                # (b,nc,nh)

    def step(h, inp):
        st, dec, Lc, Ck = inp
        y_int = jnp.einsum("btn,bhpn,bth->bthp", Ck, h, jnp.exp(Lc))
        return dec[:, :, None, None] * h + st, y_int

    h0 = jnp.zeros((b, nh, hp, N), jnp.float32)
    hF, y_inter = jax.lax.scan(
        step, h0, (jnp.moveaxis(state, 1, 0), jnp.moveaxis(chunk_decay, 1, 0),
                   jnp.moveaxis(L, 1, 0), jnp.moveaxis(Cc, 1, 0)))
    y_inter = jnp.moveaxis(y_inter, 0, 1)
    y = (y_intra + y_inter).reshape(b, Sp, nh, hp)[:, :S]
    return y.astype(x.dtype), hF
