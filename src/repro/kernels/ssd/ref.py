"""Pure-jnp oracle for the SSD intra-chunk kernel + full chunked SSD."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_intra_chunk_ref(x, dt, A, B, C):
    """Same contract as kernel.ssd_intra_chunk (G = batch*chunks)."""
    G, Q, nh, hp = x.shape
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    la = dtf * A[None, None, :]
    L = jnp.cumsum(la, axis=1)                           # (G,Q,nh)
    CB = jnp.einsum("gtn,gsn->gts", C.astype(jnp.float32),
                    B.astype(jnp.float32))
    diff = L[:, :, None, :] - L[:, None, :, :]           # (G,t,s,nh)
    causal = jnp.tril(jnp.ones((Q, Q), bool))[None, :, :, None]
    M = jnp.where(causal, CB[..., None] * jnp.exp(jnp.where(causal, diff, 0.0)), 0.0)
    M = M * dtf[:, None, :, :]
    y = jnp.einsum("gtsh,gshp->gthp", M, xf)
    decay_end = jnp.exp(L[:, -1:, :] - L)                # (G,Q,nh)
    dB = B.astype(jnp.float32)[:, :, None, :] * (dtf * decay_end)[..., None]
    state = jnp.einsum("gshn,gshp->ghpn", dB, xf)
    return y, state, L


def ssd_full_ref(x, dt, A, B, C, chunk: int):
    """Reference full SSD via repro.models.ssm (the model-side oracle)."""
    from repro.models.ssm import ssd_chunked_ref
    return ssd_chunked_ref(x, dt, A, B, C, chunk)
