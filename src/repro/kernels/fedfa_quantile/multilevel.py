"""Two-stage (histogram -> refine) trimmed quantile over sharded row slices.

The single-pass kernel in ``kernel.py`` needs the whole row resident in one
VMEM block, which caps row length and forces the norms pass to consume
model-replicated P("data") rows.  This module removes both limits with a
B-ary count-and-partition search over the IEEE-754 bit pattern of |x|:

  * stage 1 (level 0) bins every local element by the top byte of its bit
    pattern into a per-(client, segment) 256-bin histogram and ``psum``s the
    HISTOGRAM (never the rows) over the model axis;
  * stage 2 (levels 1..3) refines one byte per level inside the bracketing
    bin, so 4 levels resolve the full 32-bit pattern of the order statistic.

For nonnegative f32 the bit pattern is monotone in the value, so after the
last level the accumulated pattern IS the exact r-th smallest magnitude —
thresholds are bit-equal to ``jnp.quantile``'s bracketing order statistics
(same f32 rank arithmetic as the single-pass kernel).  The trimmed Σw² rides
along: each level also accumulates per-bin Σx² planes, summed strictly below
the chosen bin at inner levels and inclusively at the last, which yields
S(v) = Σ x²·[x <= v] for both bracketing statistics v0, v1 without a second
pass.  Because no data value lies strictly between adjacent order statistics,
the trimmed sum at the interpolated threshold t is S(v0) when t < v1 and
S(v1) otherwise.

All four levels call ONE pallas kernel inside a ``fori_loop`` (the level's
bit shift is a scalar input), so the traced program contains exactly one
row-sized read site: the read-once property survives arbitrary row length.
Per level the cross-shard traffic is the (rows, 2, segments, 256) count and
Σx² planes — histogram-sized, independent of row length, never O(N).

The kernel itself is segment-aware: it consumes the whole local flat slice
(rows, cols) at once with a static per-column segment id map (-1 marks inert
padding), building per-segment one-hot matrices so the histogram update is
two MXU-friendly (segments, tile) @ (tile, bins) matmuls per (client, rank
path).  Counts accumulate as int32 (exact past 2^24 elements); the in-bracket
test compares ``bits >> (shift+8)`` against the resolved prefix, which stays
below 2^24 so the f32 one-hot gather of the expected prefix is exact.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_BINS = 256          # one byte per level: 4 levels cover the 32-bit pattern
_LEVELS = 4
_PATHS = 2           # floor and ceil ranks bracketing the quantile position
TILE = 512          # column tile (lane-aligned); callers pad cols to this


def _hist_level_kernel(shift_ref, hi_ref, x_ref, seg_ref, sc_ref, cnt_ref,
                       sq_ref):
    """One refinement level: per-(client, path, segment) histogram planes.

    shift_ref (1, 1) i32: the level's bit shift (24, 16, 8, 0).
    hi_ref (m, P, S) i32: expected resolved prefix ``lo >> (shift+8)``.
    x_ref (m, T) column tile (f32, or the quantized admission dtype);
    seg_ref (1, T) i32 segment ids (-1 = pad).
    sc_ref (m, S) f32 per-(client, segment) dequant scales: the byte walk
    bins DEQUANTIZED magnitudes — the scale is gathered per column through
    the same segment one-hot the histograms use (all-ones on the f32 path,
    where the multiply is exact).
    cnt_ref (m, P, S, B) i32 / sq_ref (m, P, S, B) f32: accumulated over the
    column grid (zeroed on the first tile, += on revisits).
    """
    @pl.when(pl.program_id(0) == 0)
    def _init():
        cnt_ref[...] = jnp.zeros_like(cnt_ref)
        sq_ref[...] = jnp.zeros_like(sq_ref)

    shift = shift_ref[0, 0]
    hs = jnp.minimum(shift + 8, 31)      # bit 31 of |x| patterns is 0
    m, T = x_ref.shape
    _, P, S, B = cnt_ref.shape
    seg = seg_ref[0, :]                                       # (T,)
    valid = seg >= 0
    seg_oh = jnp.where(
        valid[:, None],
        (seg[:, None] == jax.lax.broadcasted_iota(jnp.int32, (T, S), 1))
        .astype(jnp.float32),
        0.0)                                                  # (T, S)
    # scales are nonnegative, so |x·scale| = |x|·scale; inert columns get
    # scale 0 but are excluded from every histogram by seg_oh anyway
    scl = jax.lax.dot_general(
        sc_ref[...].astype(jnp.float32), seg_oh,
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)                   # (m, T)
    x = jnp.abs(x_ref[...].astype(jnp.float32) * scl)         # (m, T)
    bits = jax.lax.bitcast_convert_type(x, jnp.int32)         # monotone
    binv = jax.lax.shift_right_logical(bits, shift) & (B - 1)
    hi = jax.lax.shift_right_logical(bits, hs)                # < 2^24
    iota_b = jax.lax.broadcasted_iota(jnp.int32, (T, B), 1)
    for c in range(m):
        x2 = x[c] * x[c]
        for p in range(P):
            # expected prefix per column via exact f32 one-hot gather
            hi_e = jnp.dot(seg_oh, hi_ref[c, p].astype(jnp.float32))
            inb = (hi[c] == hi_e.astype(jnp.int32)) & valid   # (T,)
            bin_oh = jnp.where(
                inb[:, None] & (iota_b == binv[c][:, None]), 1.0, 0.0)
            cnt_ref[c, p] += jnp.dot(seg_oh.T, bin_oh).astype(jnp.int32)
            sq_ref[c, p] += jnp.dot(seg_oh.T, bin_oh * x2[:, None])


def _hist_call(x, seg_id, sc, hi, shift, *, interpret: bool):
    m, C = x.shape
    _, P, S = hi.shape
    T = min(C, TILE)
    assert C % T == 0
    out_shape = [jax.ShapeDtypeStruct((m, P, S, _BINS), jnp.int32),
                 jax.ShapeDtypeStruct((m, P, S, _BINS), jnp.float32)]
    return pl.pallas_call(
        _hist_level_kernel,
        grid=(C // T,),
        in_specs=[pl.BlockSpec((1, 1), lambda i: (0, 0)),
                  pl.BlockSpec((m, P, S), lambda i: (0, 0, 0)),
                  pl.BlockSpec((m, T), lambda i: (0, i)),
                  pl.BlockSpec((1, T), lambda i: (0, i)),
                  pl.BlockSpec((m, S), lambda i: (0, 0))],
        out_specs=[pl.BlockSpec((m, P, S, _BINS), lambda i: (0, 0, 0, 0)),
                   pl.BlockSpec((m, P, S, _BINS), lambda i: (0, 0, 0, 0))],
        out_shape=out_shape,
        interpret=interpret,
    )(shift.reshape(1, 1), hi, x, seg_id.reshape(1, C), sc)


def segmented_trimmed_stats(x, seg_id, seg_len, q_seg, *, scales=None,
                            axis_name=None, interpret: bool = False):
    """Exact per-(row, segment) (threshold, trimmed Σw²) over a flat slice.

    x (m, C): each row is one client's local slice of the flat cohort
    buffer (the model shard's columns when ``axis_name`` is set, the whole
    row otherwise).  seg_id (C,) i32 maps each local column to its global
    segment (-1 marks inert padding).  seg_len (S,) i32 holds the GLOBAL
    element count per segment; q_seg (m, S) f32 the quantile levels.

    ``scales`` (m, S) declares x quantized (int8/bf16): the rows stay in
    the admitted dtype and the kernel dequantizes per column through the
    per-segment constants, so the byte walk operates on dequantized
    magnitudes with no extra row pass.  None keeps the f32 path (all-ones
    scales in-kernel; the multiply is exact).

    Returns (t, ss), both (m, S) f32 and replicated across ``axis_name``:
    t[c, s] = jnp.quantile(dequantized |x| restricted to segment s,
    q_seg[c, s]) — bit-equal to the single-pass kernel — and
    ss = Σ x²·[|x| <= t] in dequantized units.

    With ``axis_name`` every shard runs the same refinement trajectory on
    psum'd histograms, so no shard ever sees another shard's rows.
    """
    m, C = x.shape
    S = int(seg_len.shape[0])
    if scales is None:
        x = x.astype(jnp.float32)
        sc = jnp.ones((m, S), jnp.float32)
    else:
        sc = scales.astype(jnp.float32)
    seg_id = seg_id.astype(jnp.int32)
    nseg = seg_len.astype(jnp.int32)
    p = q_seg.astype(jnp.float32) * (nseg - 1).astype(jnp.float32)[None, :]
    i0 = jnp.floor(p)
    frac = p - i0                                             # (m, S)
    r0 = i0.astype(jnp.int32)
    r1 = jnp.minimum(r0 + 1, (nseg - 1)[None, :])
    rank0 = jnp.stack([r0, r1], axis=1)                       # (m, P, S)
    lo0 = jnp.zeros((m, _PATHS, S), jnp.int32)
    sq0 = jnp.zeros((m, _PATHS, S), jnp.float32)

    def level(j, carry):
        lo, rank, sqb = carry
        shift = (24 - 8 * j).astype(jnp.int32)
        hi = jax.lax.shift_right_logical(lo, jnp.minimum(shift + 8, 31))
        cnt, sq = _hist_call(x, seg_id, sc, hi, shift, interpret=interpret)
        if axis_name is not None:
            cnt = jax.lax.psum(cnt, axis_name)
            sq = jax.lax.psum(sq, axis_name)
        cum = jnp.cumsum(cnt, axis=-1)                        # (m,P,S,B)
        # smallest bin with cumulative count > rank
        bstar = jnp.sum((cum <= rank[..., None]).astype(jnp.int32), axis=-1)
        prev = jnp.maximum(bstar - 1, 0)[..., None]
        below = jnp.where(
            bstar > 0, jnp.take_along_axis(cum, prev, axis=-1)[..., 0], 0)
        sq_cum = jnp.cumsum(sq, axis=-1)
        sq_below = jnp.where(
            bstar > 0, jnp.take_along_axis(sq_cum, prev, axis=-1)[..., 0], 0.0)
        sq_incl = jnp.take_along_axis(sq_cum, bstar[..., None], axis=-1)[..., 0]
        # inner levels: Σx² strictly below the bracket; last level: inclusive,
        # completing S(v) = Σ x²·[x <= v] for the resolved order statistic
        sqb = sqb + jnp.where(j == _LEVELS - 1, sq_incl, sq_below)
        rank = rank - below
        lo = lo + jax.lax.shift_left(bstar, shift)
        return lo, rank, sqb

    lo, _, sqb = jax.lax.fori_loop(0, _LEVELS, level, (lo0, rank0, sq0))
    v = jax.lax.bitcast_convert_type(lo, jnp.float32)         # (m, P, S)
    v0, v1 = v[:, 0], v[:, 1]
    # jnp.quantile's exact linear-interpolation arithmetic (bit-equal)
    t = v0 * (1.0 - frac) + v1 * frac
    # no data value lies strictly between adjacent order statistics
    ss = jnp.where(t < v1, sqb[:, 0], sqb[:, 1])
    return t, ss


@functools.partial(jax.jit, static_argnames=("interpret",))
def row_trimmed_stats_multilevel(rows, q, *, scale=None,
                                 interpret: bool = False):
    """Drop-in for ``row_trimmed_stats`` on rows too long for one VMEM block.

    rows (R, L) signed, q (R,) levels.  Each row is its own single-segment
    client; column padding to the tile size is marked inert via seg id -1.
    ``scale`` (R,) is the per-row dequant scale of quantized rows (the rows
    keep their admitted dtype end to end).
    """
    R, L = rows.shape
    Cp = -(-L // TILE) * TILE
    if scale is None:
        rows = rows.astype(jnp.float32)
    if Cp != L:
        rows = jnp.zeros((R, Cp), rows.dtype).at[:, :L].set(rows)
    col = jax.lax.iota(jnp.int32, Cp)
    seg_id = jnp.where(col < L, 0, -1)
    seg_len = jnp.full((1,), L, jnp.int32)
    t, ss = segmented_trimmed_stats(
        rows, seg_id, seg_len, q.reshape(R, 1).astype(jnp.float32),
        scales=None if scale is None else
        scale.reshape(R, 1).astype(jnp.float32),
        interpret=interpret)
    return t[:, 0], ss[:, 0]


def histogram_elems(rows: int, segs: int) -> int:
    """Upper bound on one level's cross-shard histogram payload in elements
    (count + Σx² planes, even if XLA merges them into one tuple all-reduce):
    independent of row length, never O(N).  ``rows`` is the per-data-shard
    client count."""
    return 2 * rows * _PATHS * segs * _BINS


def multilevel_quantile_contract(slice_bytes=None, *, padded: bool = False,
                                 name: str = "quantile/multilevel"):
    """Declared contract of the two-stage path: however long the row, the
    traced program contains exactly ONE row-sized read site (the histogram
    pallas_call inside the level loop — while bodies are recursed, the call
    is one static site) and zero sorts.  ``padded=True`` covers the
    non-tile-dividing wrapper, whose pad-copy adds one read + scatter.
    ``slice_bytes`` (the local (m, C) slice) budgets the compiled peak at 6x
    the slice: the slice, its padded copy and interpret staging."""
    from repro.analysis.contracts import Contract
    peak = {} if slice_bytes is None else dict(
        peak_live_bytes_per_device=(None, 6 * slice_bytes))
    return Contract(name=name,
                    description="two-stage multilevel trimmed quantile",
                    row_reads=(1, 2) if padded else 1, sorts=0, **peak)


def distributed_quantile_contract(rows: int, segs: int, slice_bytes=None,
                                  peak_mult: int = 8):
    """ISSUE 9 / PR 7 follow-up (b): the distributed trimmed-norm pass over
    P("data","model") rows.  Exactly 1 row read, 0 sorts, and ZERO gathers
    or re-layout collectives — the only cross-shard traffic is the psum of
    the per-level histogram planes, bounded at 2·rows·paths·segs·bins
    elements (count + Σx² planes; histogram-sized, never O(N)).  ``rows``
    is the PER-DATA-SHARD client count; ``slice_bytes`` the local
    (rows, N/model) slice, budgeting the peak WITHOUT the retired
    model-replicated (m/D, N) transient."""
    from repro.analysis.contracts import Contract
    hist = histogram_elems(rows, segs)
    peak = {} if slice_bytes is None else dict(
        peak_live_bytes_per_device=(None, peak_mult * slice_bytes))
    return Contract(name="quantile/dist",
                    description="distributed two-stage trimmed quantile",
                    row_reads=1, sorts=0,
                    all_gathers=0, reduce_scatters=0, all_to_alls=0,
                    collective_permutes=0,
                    allreduce_max_elems=hist, **peak)
