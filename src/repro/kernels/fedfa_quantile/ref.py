"""Pure-jnp oracle for the fused trimmed-quantile kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def row_trimmed_stats_ref(rows, q):
    """(t, ss) per row: t[r] = jnp.quantile(|rows[r]|, q[r]) and
    ss[r] = Σ rows[r]²·[|rows[r]| <= t[r]].  rows (R, L), q (R,) -> (R,), (R,)."""
    a = jnp.abs(rows.astype(jnp.float32))
    t = jax.vmap(jnp.quantile)(a, q.astype(jnp.float32))
    ss = jnp.sum(jnp.where(a <= t[:, None], a * a, 0.0), axis=-1)
    return t, ss
