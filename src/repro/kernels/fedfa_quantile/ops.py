"""jit'd wrapper for the fused trimmed-quantile kernel (padding + dispatch)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.fedfa_quantile import multilevel, ref
from repro.kernels.fedfa_quantile.kernel import quantile_fused

_LANES = 128
_BLOCK_ROWS = 8
# Per-invocation element budget for the SINGLE-PASS kernel only: it holds
# the f32 block, its int32 bit view and a few same-shaped intermediates in
# VMEM (~16B/element), so 2^18 elements keeps a block under ~4 MiB of the
# ~16 MiB/core budget.  block_rows shrinks as rows get longer to stay
# inside it; rows longer than the whole budget dispatch to the two-stage
# multilevel kernel (still read-once, still sort-free) — NEVER to the jnp
# oracle.  The oracle runs only when the caller explicitly deselects the
# kernel path (use_kernel=False without interpret).
_SINGLE_PASS_ELEMS = 1 << 18


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def fused_quantile_contract(block_bytes=None, *, padded: bool = False):
    """Declared contract of the fused trimmed-quantile path (PR 4): the
    whole (threshold, trimmed Σw²) computation is ONE pallas_call, so the
    traced program reads the cohort row block exactly once and contains
    zero sort/top_k ops — the 31-step count-and-partition refinement
    happens in VMEM.  Checked on the jaxpr (``row_reads``/``sorts``), not
    on timing; see ``repro.analysis.jaxpr`` for the counting rules.

    With ``block_bytes`` (the (R, L) row-block byte size) the compiled
    program's statically estimated peak is budgeted at 6x the block —
    the block, its |.| copy and the interpret-mode staging buffers
    (measured ~4x on the canonical fixture).  A path that re-materializes
    per-refinement-step copies of the block blows it.

    ``padded=True`` declares the non-dividing dispatch shape: when (R, L)
    does not tile evenly, ``row_trimmed_stats`` stages the rows into a
    zero-initialized (Rp, Lp) block (one extra row-sized read feeding the
    pad scatter) and the compiled program keeps BOTH blocks live across
    the copy — the peak budget widens to 9x (measured ~6.2x on the
    canonical non-dividing fixture, vs ~4x divisible)."""
    from repro.analysis.contracts import Contract
    mult, reads = (9, (1, 2)) if padded else (6, 1)
    peak = {} if block_bytes is None else dict(
        peak_live_bytes_per_device=(None, mult * block_bytes))
    return Contract(name="quantile/fused-pad" if padded else "quantile/fused",
                    description="fused Pallas trimmed quantile"
                    + (" (non-dividing padded dispatch)" if padded else ""),
                    row_reads=reads, sorts=0, **peak)


def topk_tail_contract(block_bytes=None, *, padded: bool = False):
    """Declared shape of the top_k tail path the fused kernel replaced —
    kept as a pinned reference point: 7 row-block reads (abs, sort,
    compare, square-reduce chain) and exactly 1 sort.  If a jax upgrade
    shifts these counts the benchmark's fused-vs-topk comparison basis
    moved and the numbers need re-anchoring.  ``block_bytes`` budgets the
    compiled peak at 4x the block (measured ~2.1x); ``padded=True``
    re-anchors for the non-dividing fixture, where XLA's top_k scratch
    rounds the sorted copies up to the padded block (budget 5x)."""
    from repro.analysis.contracts import Contract
    mult = 5 if padded else 4
    peak = {} if block_bytes is None else dict(
        peak_live_bytes_per_device=(None, mult * block_bytes))
    return Contract(name="quantile/topk-pad" if padded else "quantile/topk",
                    description="top_k tail path (pre-PR 4 reference)",
                    row_reads=7, sorts=1, **peak)


@functools.partial(jax.jit, static_argnames=("use_kernel", "interpret"))
def row_trimmed_stats(rows: jax.Array, q: jax.Array, *,
                      scale: jax.Array = None,
                      use_kernel=None, interpret: bool = False) -> tuple:
    """Fused per-row (quantile threshold, trimmed Σw²) in ONE pass.

    rows: (R, L) signed values (|.| is taken inside the kernel);
    q: (R,) quantile levels in [0, 1].  Returns f32 ((R,), (R,)):
    t[r] = jnp.quantile(|rows[r]|, q[r]) and
    ss[r] = Σ rows[r]²·[|rows[r]| <= t[r]].

    ``scale`` (R,) declares the rows quantized (int8/bf16): the kernel
    paths keep the admitted dtype in HBM and dequantize in VMEM through
    the per-row constant, preserving read-once; only the explicit-oracle
    path materializes the f32 product.

    Dispatch: rows that fit one VMEM block go to the single-pass kernel;
    longer rows (embedding-scale leaves) go to the two-stage multilevel
    kernel.  Both are read-once and sort-free; the jnp oracle runs ONLY
    when the caller explicitly deselects the kernel path.
    """
    if use_kernel is None:
        use_kernel = _on_tpu()
    R, L = rows.shape
    if not (use_kernel or interpret):
        if scale is not None:
            rows = rows.astype(jnp.float32) \
                * scale[:, None].astype(jnp.float32)
        return ref.row_trimmed_stats_ref(rows, q)
    Lp = ((L + _LANES - 1) // _LANES) * _LANES
    if Lp > _SINGLE_PASS_ELEMS:
        return multilevel.row_trimmed_stats_multilevel(
            rows, q, scale=scale, interpret=interpret or not _on_tpu())
    rb = max(1, min(_BLOCK_ROWS, R, _SINGLE_PASS_ELEMS // Lp))
    Rp = ((R + rb - 1) // rb) * rb
    want = rows.dtype if scale is not None else jnp.float32
    if Lp == L and Rp == R:
        rows_p, q_p = rows.astype(want), q.astype(jnp.float32)
        s_p = None if scale is None else scale.astype(jnp.float32)
    else:
        # lane pads are masked out in-kernel (any value works); row pads get
        # q = 1 on zero rows (t = 0, ss = 0) and are sliced off below
        rows_p = jnp.zeros((Rp, Lp), want).at[:R, :L].set(rows.astype(want))
        q_p = jnp.ones((Rp,), jnp.float32).at[:R].set(q.astype(jnp.float32))
        s_p = None if scale is None else \
            jnp.ones((Rp,), jnp.float32).at[:R].set(
                scale.astype(jnp.float32))
    t, ss = quantile_fused(rows_p, q_p, L=L, block_rows=rb, scale=s_p,
                           interpret=interpret or not _on_tpu())
    return t[:R], ss[:R]
