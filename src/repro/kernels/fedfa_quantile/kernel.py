"""Fused trimmed-quantile Pallas kernel for the flat aggregation engine.

One kernel invocation owns a block of (client, segment) rows and computes,
entirely from VMEM, BOTH outputs of the flat engine's trimmed-norm pass:

  * the per-row quantile threshold t = quantile(|row|, q) with
    ``jnp.quantile``'s linear interpolation between the two bracketing
    order statistics, and
  * the trimmed sum of squares Σ w²·[|w| <= t].

The order statistics are found WITHOUT sorting: for nonnegative f32 values
the IEEE-754 bit pattern is monotone in the value, so the r-th smallest
magnitude is located by a 31-step binary search over int32 bit patterns
(count-and-partition: count entries whose pattern <= mid, narrow the
bracket).  Every refinement step is a VPU compare+sum over the VMEM-resident
row block — the row is read from HBM exactly once, versus the top_k path's
sort + gather + compare + square chain (each its own pass over the data).

Ties need no special casing: counting "<= mid" puts every duplicate of a
value on the same side of the partition, so the search lands on the exact
tied value and the trim test ``|w| <= t`` then keeps all of its copies —
identical to what a sort-based selection yields.

ops.py handles padding (lane alignment, row blocking) and CPU dispatch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Bit pattern of +inf: upper bound of the search bracket and the sentinel
# for lane-padding columns (never selected — every real magnitude is finite
# and the bracket collapses onto real data before reaching it).  Plain int:
# a module-level jnp scalar would be a captured constant in the kernel.
_INF_BITS = 0x7F800000
# ceil(log2(2**31)) halvings collapse [0, _INF_BITS] to a single pattern.
_ITERS = 31


def _quantile_fused_kernel(rows_ref, q_ref, s_ref, t_ref, ss_ref, *, L: int):
    # s_ref (rb, 1): per-row dequant scale — quantized rows (int8/bf16)
    # upcast in VMEM and scale on the fly, so the quantile walks dequantized
    # magnitudes in the same single read.  f32 rows pass scale 1.0 (the
    # multiply is exact, preserving bit-equality with jnp.quantile).
    x = jnp.abs(rows_ref[...].astype(jnp.float32) * s_ref[...])   # (rb, Lp)
    col = jax.lax.broadcasted_iota(jnp.int32, x.shape, 1)
    valid = col < L
    bits = jax.lax.bitcast_convert_type(x, jnp.int32)         # monotone
    bits = jnp.where(valid, bits, _INF_BITS)

    q = q_ref[...]                                            # (rb, 1)
    p = q * (L - 1.0)                                         # sort position
    i0 = jnp.floor(p)
    frac = p - i0
    r0 = i0.astype(jnp.int32)                                 # floor rank
    r1 = jnp.minimum(r0 + 1, L - 1)                           # ceil rank

    def select(rank):
        """Exact rank-th smallest magnitude per row (0-indexed ascending)."""
        def body(_, lh):
            lo, hi = lh
            mid = lo + (hi - lo) // 2                         # (rb, 1)
            cnt = jnp.sum((bits <= mid).astype(jnp.int32),
                          axis=1, keepdims=True)
            ge = cnt >= rank + 1
            return jnp.where(ge, lo, mid + 1), jnp.where(ge, mid, hi)
        lo = jnp.zeros_like(rank)
        hi = jnp.full_like(rank, _INF_BITS)
        lo, _ = jax.lax.fori_loop(0, _ITERS, body, (lo, hi))
        return jax.lax.bitcast_convert_type(lo, jnp.float32)

    v0 = select(r0)
    v1 = select(r1)
    # jnp.quantile's exact linear-interpolation arithmetic (bit-equal;
    # v0 + (v1 - v0)*frac can land one ulp away on long rows)
    t = v0 * (1.0 - frac) + v1 * frac                         # (rb, 1)
    keep = valid & (x <= t)
    t_ref[...] = t
    ss_ref[...] = jnp.sum(jnp.where(keep, x * x, 0.0), axis=1, keepdims=True)


def quantile_fused(rows: jax.Array, q: jax.Array, *, L: int,
                   block_rows: int = 8, scale: jax.Array = None,
                   interpret: bool = False) -> tuple:
    """rows: (R, Lp) signed, lane-padded past column L with zeros;
    q: (R,) quantile levels in [0, 1].  R % block_rows == 0, Lp % 128 == 0.
    ``scale`` (R,) dequantizes int8/bf16 rows in-kernel (None = f32 rows,
    scale 1).  Returns (t, ss) f32 (R,): the |.|-quantile threshold and
    trimmed Σw², both in dequantized units."""
    R, Lp = rows.shape
    assert R % block_rows == 0 and Lp % 128 == 0 and 1 <= L <= Lp
    nb = R // block_rows
    if scale is None:
        scale = jnp.ones((R,), jnp.float32)
    kernel = functools.partial(_quantile_fused_kernel, L=L)
    t, ss = pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((block_rows, Lp), lambda i: (i, 0)),
                  pl.BlockSpec((block_rows, 1), lambda i: (i, 0)),
                  pl.BlockSpec((block_rows, 1), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((block_rows, 1), lambda i: (i, 0)),
                   pl.BlockSpec((block_rows, 1), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((R, 1), jnp.float32),
                   jax.ShapeDtypeStruct((R, 1), jnp.float32)],
        interpret=interpret,
    )(rows, q.reshape(R, 1).astype(jnp.float32),
      scale.reshape(R, 1).astype(jnp.float32))
    return t[:, 0], ss[:, 0]
