PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: tier1 check bench-round bench-aggregate

tier1:            ## fast test suite (the driver's acceptance gate)
	$(PY) -m pytest -x -q

check:            ## tier-1 tests + resident-round smoke bench (CI gate)
	$(PY) benchmarks/run.py --check

bench-round:      ## resident vs per-round driver, m in {4,16,64} -> BENCH_round.json
	$(PY) benchmarks/bench_round.py

bench-aggregate:  ## flat vs tree aggregation engines -> BENCH_aggregate.json
	$(PY) benchmarks/bench_aggregate.py
