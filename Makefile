PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: tier1 check lint analysis analysis-json bench-round bench-aggregate bench-shard bench-shard-2d bench-quantile bench-async bench-quant

tier1:            ## fast test suite (the driver's acceptance gate)
	$(PY) -m pytest -x -q

check:            ## tier-1 tests + resident/sharded round smoke benches (CI gate)
	$(PY) benchmarks/run.py --check

lint:             ## FL-specific AST source lints over src/
	$(PY) -m repro.analysis lint src/

analysis:         ## program-contract check: lower the canonical program set, print the contract table
	$(PY) -m repro.analysis check

analysis-json:    ## program-contract check + machine-readable report -> results/ANALYSIS.json
	$(PY) -m repro.analysis check --json results/ANALYSIS.json

bench-round:      ## resident vs per-round driver, m in {4,16,64} -> BENCH_round.json
	$(PY) benchmarks/bench_round.py

bench-aggregate:  ## flat vs tree aggregation engines -> BENCH_aggregate.json
	$(PY) benchmarks/bench_aggregate.py

bench-shard:      ## sharded vs unsharded resident round (data-only + 2x2 meshes) on 4 forced CPU devices -> BENCH_shard.json
	XLA_FLAGS="$(XLA_FLAGS) --xla_force_host_platform_device_count=4" \
		$(PY) benchmarks/bench_shard.py --model-shards 1 2

bench-shard-2d:   ## 2x2 (data, model) mesh only: reduce-scattered aggregation -> results/BENCH_shard_2d.json
	XLA_FLAGS="$(XLA_FLAGS) --xla_force_host_platform_device_count=4" \
		$(PY) benchmarks/bench_shard.py --model-shards 2 \
		--out results/BENCH_shard_2d.json

bench-quant:      ## quantized-admission round (int8/bf16, fused dequantize + error feedback): bytes-on-wire + resident-byte reductions gated -> BENCH_shard.json
	XLA_FLAGS="$(XLA_FLAGS) --xla_force_host_platform_device_count=4" \
		$(PY) benchmarks/bench_shard.py --model-shards 1 2 \
		--update-dtype bf16 int8

bench-quantile:   ## fused trimmed-quantile kernel vs top_k path (4 forced CPU devices) -> BENCH_quantile.json
	XLA_FLAGS="$(XLA_FLAGS) --xla_force_host_platform_device_count=4" \
		$(PY) benchmarks/bench_quantile.py

bench-async:      ## async bounded-staleness engine vs sync driver on the skewed trace (4 forced CPU devices) -> BENCH_async.json
	XLA_FLAGS="$(XLA_FLAGS) --xla_force_host_platform_device_count=4" \
		$(PY) benchmarks/bench_async.py --min-ratio 1.3
