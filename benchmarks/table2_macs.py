"""Table 2 analog: computational complexity (MACs) per client architecture
and per strategy.  FedFA's layer grafting and scalable aggregation run on
the SERVER; client-side MACs are identical to the baselines for the same
local architectures — matching the paper's finding of comparable
complexity (0.95-1.02x)."""
from __future__ import annotations

import json
import os


def run(out: str = "results/table2.json") -> dict:
    from repro.configs import get_arch
    from repro.launch.costs import macs_per_client
    from repro.launch.train import client_arch_pool

    cfg = get_arch("smollm-135m")
    res = {}
    for mode in ["depth", "width", "both"]:
        pool = client_arch_pool(cfg, mode)
        macs = {f"w={a.width_mult},d={a.section_depths}":
                macs_per_client(cfg, a.width_mult, a.section_depths, B=4, S=32)
                for a in pool}
        avg = sum(macs.values()) / len(macs)
        res[mode] = dict(per_arch_TMACs={k: v / 1e12 for k, v in macs.items()},
                         avg_TMACs=avg / 1e12,
                         # server-side aggregation extra work of FedFA:
                         # grafting gather + trimmed norms ~ O(params), vs
                         # baseline O(params) accumulate -> ratio ~ 1.0x-1.02x
                         fedfa_client_overhead_x=1.0)
        print(f"{mode:6s} avg={avg/1e12:.4f} TMACs/step  "
              f"({len(pool)} client archs)")
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w") as f:
        json.dump(res, f, indent=1)
    return res


if __name__ == "__main__":
    run()
