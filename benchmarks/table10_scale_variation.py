"""Table 10 / Appendix F analog: scale variations across architectures of
different depth/width — the empirical motivation for scalable aggregation.
Trains a baseline + depth/width variants briefly on identical data and
reports first/last-layer average weight magnitudes and distances."""
from __future__ import annotations

import json
import os

import numpy as np


def _avg_mag(leaf):
    return float(np.mean(np.abs(np.asarray(leaf, np.float32))))


def run(quick: bool = True, out: str = "results/table10.json",
        seed: int = 0) -> dict:
    import jax
    import jax.numpy as jnp
    from repro.configs import get_arch
    from repro.data import synthetic
    from repro.launch.steps import make_train_step
    from repro.models import model as model_mod
    from repro.optim import init_opt

    base = get_arch("smollm-135m").reduced().replace(
        vocab_size=128, n_layers=2, n_sections=1)
    steps = 30 if quick else 200
    variants = {
        "baseline": base,
        "deeper+2": base.replace(n_layers=4),
        "deeper+4": base.replace(n_layers=6),
        "wider1.25x": base.replace(d_ff=int(base.d_ff * 1.25) // 8 * 8),
        "wider1.5x": base.replace(d_ff=int(base.d_ff * 1.5) // 8 * 8),
    }
    data = synthetic.lm_stream(base.vocab_size, steps * 4, 32, seed=seed)
    res = {}
    weights = {}
    for vi, (name, cfg) in enumerate(variants.items()):
        # each architecture trains from its own initialization (paper
        # Appendix F: independently trained models of different complexity)
        params = model_mod.init_params(cfg, jax.random.PRNGKey(seed + 101 * vi))
        opt = init_opt(params, "sgd")
        step = jax.jit(make_train_step(cfg, total_steps=steps))
        for s in range(steps):
            toks = jnp.asarray(data[s * 4:(s + 1) * 4])
            params, opt, _ = step(params, opt, {"tokens": toks},
                                  jnp.asarray(s + 1))
        first = params["stages"][0][0]["attn"]["wq"][0]
        last = params["stages"][0][0]["ffn"]["w_down"][-1]
        weights[name] = (np.asarray(first), np.asarray(last))
        res[name] = dict(first_layer_mag=_avg_mag(first),
                         last_layer_mag=_avg_mag(last))
    bf, bl = weights["baseline"]
    for name in variants:
        if name == "baseline":
            continue
        f, l = weights[name]
        # compare common parts only (HeteroFL/NeFL contiguous structured
        # pruning, paper Appendix F): slice wider variants to the baseline's
        # leading channels.
        fc = f[tuple(slice(0, d) for d in bf.shape)]
        lc = l[tuple(slice(0, d) for d in bl.shape)]
        res[name]["first_layer_dist"] = float(np.mean(np.abs(fc - bf)))
        res[name]["last_layer_dist"] = float(np.mean(np.abs(lc - bl)))
        res[name]["dist_over_baseline_mag"] = round(
            res[name]["first_layer_dist"] / res["baseline"]["first_layer_mag"], 3)
        print(f"{name:12s} mag={res[name]['first_layer_mag']:.4f} "
              f"dist={res[name]['first_layer_dist']:.4f} "
              f"ratio={res[name]['dist_over_baseline_mag']}")
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w") as f:
        json.dump(res, f, indent=1)
    return res


if __name__ == "__main__":
    run()
