"""Appendix B analog: residual-block similarity — the empirical
justification for layer grafting.

The paper's core argument (B.2) is *functional*: swapping residual blocks
barely changes the output, i.e. f_r(x) ≈ f_{r+1}(x) on the same input.
For CNN filters it proxies this with matched-PCC of 3x3 weight maps; for
transformer blocks (d_model-sized rows) raw weight PCC of independently
initialized matrices is ~0 by construction, so we measure the functional
quantity directly: cosine similarity between consecutive blocks' residual
updates f_r(x_r) and f_{r+1}(x_r) evaluated on the SAME stream state —
exactly the substitution grafting performs.
"""
from __future__ import annotations

import json
import os

import numpy as np


def block_functional_similarity(params, cfg, batch, seed=0) -> float:
    import jax
    import jax.numpy as jnp
    from repro.models import model as model_mod
    from repro.models.masks import full_masks
    from repro.models.transformer import _block_apply

    m = full_masks(cfg)
    x = model_mod._embed(params, cfg, batch["tokens"], m)
    positions = jnp.arange(x.shape[1])[None]
    st = params["stages"][0]
    R = cfg.stages()[0][1]
    gate = jnp.ones((), jnp.float32)
    sims = []
    for r in range(R):
        p_r = jax.tree.map(lambda t: t[r], st)
        deltas = []
        for rr in (r, min(r + 1, R - 1)):
            p_rr = jax.tree.map(lambda t: t[rr], st)
            y, _, _ = _block_apply(cfg.pattern_unit[0], p_rr[0], x, cfg, m,
                                   gate=gate, positions=positions,
                                   window=cfg.attn_window)
            deltas.append((y - x).astype(jnp.float32).reshape(-1))
        if r + 1 < R:
            a, b = deltas
            sims.append(float(a @ b / (jnp.linalg.norm(a) * jnp.linalg.norm(b)
                                       + 1e-9)))
        # advance the stream with block r
        y, _, _ = _block_apply(cfg.pattern_unit[0], p_r[0], x, cfg, m,
                               gate=gate, positions=positions,
                               window=cfg.attn_window)
        x = y
    return float(np.mean(sims))


def run(quick: bool = True, out: str = "results/appendixB.json",
        seed: int = 0) -> dict:
    import jax
    import jax.numpy as jnp
    from repro.configs import get_arch
    from repro.data import synthetic
    from repro.launch.steps import make_train_step
    from repro.models import model as model_mod
    from repro.optim import init_opt

    cfg = get_arch("smollm-135m").reduced().replace(
        vocab_size=128, n_layers=4, n_sections=1)
    steps = 60 if quick else 300
    params = model_mod.init_params(cfg, jax.random.PRNGKey(seed))
    data = synthetic.lm_stream(cfg.vocab_size, steps * 4 + 8, 32, seed=seed)
    batch0 = {"tokens": jnp.asarray(data[-8:])}
    res = {"epoch0": {"functional_cos": block_functional_similarity(
        params, cfg, batch0, seed)}}
    opt = init_opt(params, "sgd")
    step = jax.jit(make_train_step(cfg, total_steps=steps))
    for s in range(steps):
        toks = jnp.asarray(data[s * 4:(s + 1) * 4])
        params, opt, _ = step(params, opt, {"tokens": toks}, jnp.asarray(s + 1))
    res["trained"] = {"functional_cos": block_functional_similarity(
        params, cfg, batch0, seed)}
    print("residual-update similarity cos(f_r(x), f_{r+1}(x)):", res)
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w") as f:
        json.dump(res, f, indent=1)
    return res


if __name__ == "__main__":
    run()
