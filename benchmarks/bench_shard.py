"""Sharded resident-round benchmark: cohort axis over the mesh ``data`` axis.

Times the resident driver (``repro.core.round``) with and without a mesh
(``repro.launch.mesh.make_data_mesh`` — every local device on the data
axis) and inspects the lowered HLO of the sharded round program:

  * on a single-device host the mesh degenerates to 1x1 and the sharded
    program must not regress against the unsharded resident round,
  * on a multi-device backend (``XLA_FLAGS=--xla_force_host_platform_
    device_count=K`` on CPU — the CI configuration — or a real TPU slice)
    the collective counts make the sharding inspectable: the (M', γ)
    accumulation must lower to per-shard partial sums + one all-reduce per
    fused reduction, with NO all-gather materializing the (m, N) cohort.

Emits ``BENCH_shard.json`` — the sharding trajectory anchor.

  PYTHONPATH=src python benchmarks/bench_shard.py [--smoke] [--min-ratio X]
"""
from __future__ import annotations

import argparse
import json
import os
import re
import sys
from collections import Counter

try:
    from benchmarks.bench_round import _setup, _time_resident
except ImportError:                      # run as a script from benchmarks/
    from bench_round import _setup, _time_resident


def _collectives(cfg, fl, params, specs, batches, mesh):
    """Lower + compile the sharded round program and count its collectives.

    Returns (counts, full_cohort_gathers, psum_reduces): ``counts`` is a
    dict of collective-op line counts, ``full_cohort_gathers`` the number of
    all-gathers whose result is the full (m, N) cohort (must be 0), and
    ``psum_reduces`` the number of all-reduces of exactly N elements — the
    fused (M', γ) partial-sum reductions.
    """
    import jax
    import jax.numpy as jnp
    from repro.core import flat
    from repro.core.round import make_flat_round
    from repro.core.server import default_class_masks, stack_runtimes
    from repro.sharding import cohort as csh

    index = flat.get_index(params)
    runtimes = stack_runtimes(cfg, specs)
    m = len(specs)
    pad = csh.pad_rows(m, mesh)
    m_real = m if pad else None
    (masks, gates, gmaps, nd, cms, mal), bpad = csh.pad_cohort(
        runtimes, batches, pad)
    mp = m + pad
    cms_in = default_class_masks(cms, cfg, fl, mp)
    fn = make_flat_round(cfg, fl, index, any_malicious=False, mesh=mesh,
                         m_real=m_real)
    g = jax.device_put(flat.flatten(index, params), csh.replicated(mesh))
    c = jax.device_put(jnp.zeros((mp, index.n), jnp.float32),
                       csh.cohort_sharding(mesh))
    txt = fn.lower(g, c, masks, gates, gmaps, nd, cms_in, mal, bpad,
                   jax.random.PRNGKey(0)).compile().as_text()

    kinds = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
             "collective-permute")
    counts = Counter()
    full_gathers = psums = 0
    shape_re = re.compile(r'=\s*\(?([a-z0-9]+)\[([\d,]*)\]')
    for line in txt.splitlines():
        for kind in kinds:
            # sync ops lower as " all-reduce(...)"; TPU/GPU backends often
            # emit async pairs — count the "-start(" half (which carries the
            # shape), never the "-done(" half, so each op counts once
            if f" {kind}(" not in line and f" {kind}-start(" not in line:
                continue
            counts[kind] += 1
            sm = shape_re.search(line)
            if sm is None:
                continue
            dims = [int(d) for d in sm.group(2).split(",") if d]
            elems = 1
            for d in dims:
                elems *= d
            if kind == "all-gather" and elems >= mp * index.n:
                full_gathers += 1
            if kind == "all-reduce" and elems == index.n:
                psums += 1
    return dict(counts), full_gathers, psums


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--cohorts", nargs="+", type=int, default=[4, 16])
    ap.add_argument("--rounds", type=int, default=5)
    ap.add_argument("--local-steps", type=int, default=1)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq-len", type=int, default=16)
    ap.add_argument("--smoke", action="store_true",
                    help="m=4 only, 3 rounds — the tier-1 CI configuration")
    ap.add_argument("--min-ratio", type=float, default=None,
                    help="exit 1 if sharded/unsharded rounds-per-sec falls "
                         "below this (default: 0.75 on a single device, "
                         "structural checks only on multi-device)")
    ap.add_argument("--out", default=None,
                    help="output json (default: BENCH_shard.json, or "
                         "results/BENCH_shard_smoke.json with --smoke so CI "
                         "smoke runs don't clobber the checked-in anchor)")
    args = ap.parse_args()
    if args.smoke:
        args.cohorts, args.rounds = [4], 3
    if args.out is None:
        args.out = "results/BENCH_shard_smoke.json" if args.smoke \
            else "BENCH_shard.json"

    import jax
    from repro.launch.mesh import make_data_mesh

    n_dev = jax.device_count()
    mesh = make_data_mesh()
    min_ratio = args.min_ratio
    if min_ratio is None and n_dev == 1:
        # 1x1 mesh: sharding annotations must be ~free on the host path
        min_ratio = 0.75

    results = {"backend": jax.default_backend(), "n_devices": n_dev,
               "mesh": {ax: int(s) for ax, s in mesh.shape.items()},
               "config": {"rounds": args.rounds,
                          "local_steps": args.local_steps,
                          "batch": args.batch, "seq_len": args.seq_len},
               "runs": {}}
    ok = True
    for m in args.cohorts:
        cfg, fl, params, specs, batches = _setup(
            m, args.local_steps, args.batch, args.seq_len)
        dt_un = _time_resident(cfg, fl, params, specs, batches, args.rounds,
                               mesh=None)
        dt_sh = _time_resident(cfg, fl, params, specs, batches, args.rounds,
                               mesh=mesh)
        counts, full_gathers, psums = _collectives(
            cfg, fl, params, specs, batches, mesh)
        ratio = dt_un / max(dt_sh, 1e-9)
        rec = {
            "unsharded": {"mean_s": round(dt_un / args.rounds, 5),
                          "rounds_per_s": round(args.rounds / dt_un, 3)},
            "sharded": {"mean_s": round(dt_sh / args.rounds, 5),
                        "rounds_per_s": round(args.rounds / dt_sh, 3)},
            "sharded_over_unsharded": round(ratio, 3),
            "collectives": counts,
            "full_cohort_all_gathers": full_gathers,
            "n_psum_reduces": psums,
        }
        results["runs"][f"m{m}"] = rec
        print(f"m={m:3d}  unsharded {rec['unsharded']['rounds_per_s']:7.2f} "
              f"r/s  sharded {rec['sharded']['rounds_per_s']:7.2f} r/s  "
              f"ratio {ratio:.2f}x  collectives {counts}", flush=True)
        if full_gathers:
            print(f"FAIL: {full_gathers} all-gather(s) materialize the full "
                  f"(m, N) cohort at m={m}", flush=True)
            ok = False
        if n_dev > 1 and counts.get("all-gather", 0) > 0:
            # the round has no legitimate all-gather at all today; a nonzero
            # count means cohort data is being re-replicated somewhere (the
            # leaf-by-leaf top_k re-gather is each smaller than m*N, so the
            # full-cohort check alone would miss it)
            print(f"FAIL: {counts['all-gather']} all-gather(s) in the "
                  f"sharded round at m={m} — cohort data is being "
                  f"re-replicated", flush=True)
            ok = False
        if n_dev > 1 and psums < 1:
            print(f"FAIL: no N-sized all-reduce in the sharded round at "
                  f"m={m} — the (M', γ) reduction is not a per-shard "
                  f"partial sum + psum", flush=True)
            ok = False
        if min_ratio is not None and ratio < min_ratio:
            print(f"FAIL: sharded/unsharded ratio {ratio:.2f} < required "
                  f"{min_ratio:.2f} at m={m}", flush=True)
            ok = False

    out = args.out if os.path.isabs(args.out) else os.path.normpath(
        os.path.join(os.path.dirname(os.path.abspath(__file__)), "..",
                     args.out))
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w") as f:
        json.dump(results, f, indent=1)
    print(f"wrote {out}")
    if not ok:
        sys.exit(1)


if __name__ == "__main__":
    main()
