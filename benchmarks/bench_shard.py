"""Sharded resident-round benchmark: client axis over ``data``, parameter
axis over ``model``.

Times the resident driver (``repro.core.round``) without a mesh and under
one mesh per requested ``--model-shards`` value (1 -> the PR 3 data-only
mesh with every local device on ``data``; k > 1 -> a real 2-D
(n_dev/k, k) ``(data, model)`` mesh), and inspects the lowered HLO:

  * on a single-device host the mesh degenerates to 1x1 and the sharded
    program must not regress against the unsharded resident round,
  * on a multi-device backend (``XLA_FLAGS=--xla_force_host_platform_
    device_count=K`` on CPU — the CI configuration — or a real TPU slice)
    the collective counts make the sharding inspectable.  The aggregation
    path (``flat.aggregate_buffers`` lowered standalone on the round's own
    shardings) must show ZERO all-gathers; with model shards the (M', γ)
    reductions must lower to reduce-scatters with no all-reduce above
    N/n_model elements (per-device volume ~N/n_model), and the full round
    may all-gather only the global-model broadcast (<= N elements), never
    cohort-scale data.  Per-device resident-buffer bytes (g_buf N/n_model,
    c_buf (m/D)·(N/n_model), f32) are recorded alongside the counts.

With ``--update-dtype`` a quantized section rides along on the data-only
mesh: the int8/bf16 admission round (per-segment scales, fused
dequantize, server-side error feedback) is timed and lowered, its
``quantized_round_contract`` gated (zero all-gathers, five donated
pools, peak budget, read-once fused dequantize), and the bytes-on-wire
and per-device resident-byte reductions recorded — the int8 wire
reduction is gated >= 3.5x.

Emits ``BENCH_shard.json`` — the sharding trajectory anchor (see its
``schema_notes`` for the gated invariant).

  PYTHONPATH=src python benchmarks/bench_shard.py [--smoke] \
      [--model-shards K ...] [--min-ratio X] [--update-dtype [DT ...]]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from collections import Counter

try:
    from benchmarks.bench_round import _setup, _time_resident
except ImportError:                      # run as a script from benchmarks/
    from bench_round import _setup, _time_resident

SCHEMA_NOTES = (
    "Gated collective-structure invariant per (m, ms) run: "
    "agg_collectives (flat.aggregate_buffers lowered standalone on the "
    "round's shardings) must have all_gathers == 0 always; with "
    "model_shards > 1 it must have reduce_scatters >= 1 and every "
    "N-scale all-reduce exactly n_padded/model_shards elements "
    "(per-device all-reduce volume ~N/n_model); with model_shards == 1 "
    "it keeps PR 3's 1-2 N-sized psums.  The full round "
    "('collectives') must never all-gather the full (m, N) cohort: "
    "full_cohort_all_gathers == 0.  max_all_gather_elems is "
    "informational — mostly the <= N global-model broadcast into local "
    "training, though GSPMD may re-layout training intermediates over "
    "the idle model axis.  per_device_bytes records the RESIDENT "
    "buffer footprint (f32): g_buf = n_padded/model_shards, "
    "c_buf = (m_padded/data_shards)*(n_padded/model_shards).  "
    "The optional 'quantized' section (--update-dtype, data-only mesh) "
    "records the quantized-admission round per dtype: "
    "bytes_on_wire_per_client is the per-round client upload "
    "(f32 n_padded*4 vs n_padded*itemsize + n_segments*4 scales; the "
    "int8 reduction is gated >= 3.5x), per_device_resident_bytes the "
    "inter-round server state (f32 scratch vs two admitted-dtype pools "
    "[rows + error feedback] plus two f32 scale tables, ~2x at int8), "
    "and 'contract' the gated quantized_round_contract (zero "
    "all-gathers, five donated pools, peak budget, read-once fused "
    "dequantize)."
)

def _mesh_inputs(cfg, fl, params, specs, batches, mesh, *,
                 with_scratch=False):
    """The padded (index, runtime, buffer) set the sharded round sees.

    The (mp, n_padded) zero cohort scratch ``c`` is only materialized when
    ``with_scratch`` is set (the round lowering needs it as a donated
    argument; the standalone aggregation lowering does not) — at m=64 it is
    a ~600MB device buffer."""
    import jax
    import jax.numpy as jnp
    from repro.core import flat
    from repro.core.server import default_class_masks, stack_runtimes
    from repro.sharding import cohort as csh

    index = flat.get_index(params, pad_to=csh.pad_unit(mesh))
    runtimes = stack_runtimes(cfg, specs)
    m = len(specs)
    pad = csh.pad_rows(m, mesh)
    m_real = m if pad else None
    (masks, gates, gmaps, nd, cms, mal), bpad = csh.pad_cohort(
        runtimes, batches, pad)
    mp = m + pad
    cms_in = default_class_masks(cms, cfg, fl, mp)
    g = jax.device_put(flat.flatten(index, params), csh.global_sharding(mesh))
    c = None
    if with_scratch:
        c = jax.device_put(jnp.zeros((mp, index.n_padded), jnp.float32),
                           csh.cohort_buffer_sharding(mesh))
    return (index, m_real, mp, (masks, gates, gmaps, nd, cms_in, mal, bpad),
            g, c)


def _collectives(cfg, fl, params, specs, batches, mesh):
    """Lower + compile the sharded ROUND program and count its collectives.

    Returns (counts, full_cohort_gathers, psum_reduces, max_gather_elems):
    ``counts`` is a dict of collective-op line counts,
    ``full_cohort_gathers`` the number of all-gathers whose result is the
    full (m, N) cohort (must be 0), ``psum_reduces`` the number of
    all-reduces of exactly n_padded elements — the fused (M', γ)
    partial-sum reductions of the data-only layout — and
    ``max_gather_elems`` the largest all-gather result (with model shards
    this must stay <= n_padded: the global-model broadcast).
    """
    import jax
    from repro.analysis import hlo
    from repro.core.round import make_flat_round

    (index, m_real, mp, (masks, gates, gmaps, nd, cms_in, mal, bpad),
     g, c) = _mesh_inputs(cfg, fl, params, specs, batches, mesh,
                          with_scratch=True)
    fn = make_flat_round(cfg, fl, index, any_malicious=False, mesh=mesh,
                         m_real=m_real)
    keys = jax.random.split(jax.random.PRNGKey(0), mp)
    txt = fn.lower(g, c, masks, gates, gmaps, nd, cms_in, mal, bpad,
                   keys).compile().as_text()

    ops = hlo.collectives(txt)
    counts = Counter(op.kind for op in ops)
    gathers = [op.elems for op in ops
               if op.kind == "all-gather" and op.elems is not None]
    full_gathers = sum(1 for e in gathers if e >= mp * index.n_padded)
    max_gather = max(gathers, default=0)
    psums = sum(1 for op in ops
                if op.kind == "all-reduce" and op.elems == index.n_padded)
    from repro.core.round import round_contract
    report = round_contract(index, mesh, rows=mp).check(hlo=txt)
    return dict(counts), full_gathers, psums, max_gather, report


def _agg_collectives(cfg, fl, params, specs, batches, mesh):
    """Lower the AGGREGATION path standalone (the round's own shardings:
    g over ``model``, x over ``data`` pre-split) and count its collectives.

    Returns (all_gathers, reduce_scatters, big_allreduce_sizes) where the
    sizes list every all-reduce of >= n_padded/model_shards elements —
    with model shards these must all be exactly n_padded/model_shards.
    """
    import jax
    from repro.analysis import hlo
    from repro.core import flat
    from repro.sharding import cohort as csh

    (index, _, mp, (masks, gates, gmaps, nd, _, _, _), g, _) = _mesh_inputs(
        cfg, fl, params, specs, batches, mesh)
    x = jax.device_put(
        jax.random.normal(jax.random.PRNGKey(1), (mp, index.n_padded),
                          jax.numpy.float32), csh.cohort_sharding(mesh))
    fn = jax.jit(lambda g, x, nd: flat.aggregate_buffers(
        index, g, x, cfg, masks, gates, gmaps, nd, graft=True, scale=True,
        use_kernel=True, interpret=True, mesh=mesh),
        out_shardings=csh.global_sharding(mesh))
    txt = fn.lower(g, x, nd).compile().as_text()
    scale = index.n_padded // csh.model_shards(mesh)
    return (hlo.count(txt, "all-gather"), hlo.count(txt, "reduce-scatter"),
            hlo.sizes(txt, "all-reduce", min_elems=scale))


def _quant_collectives(cfg, fl, params, specs, batches, mesh, dt):
    """Lower + compile the QUANTIZED round (``--update-dtype int8``/
    ``bf16``: quantized admission with per-segment scales, dequantize
    fused into the accumulate kernel, server-side error feedback) on the
    data-only mesh and check ``quantized_round_contract``.

    The HLO gates (zero all-gathers, donated five-buffer ping-pong, peak
    budget) are measured on the compiled round; the read-once/sort-free
    fused-dequantize gates on a standalone ``accumulate_quant`` trace over
    the admitted-dtype rows (the full round's jaxpr touches row-sized f32
    training transients, so the kernel invariant is pinned where it
    lives).  Returns (collective counts, contract report)."""
    import dataclasses

    import jax
    import jax.numpy as jnp
    from repro.analysis import hlo
    from repro.core import flat
    from repro.core import round as round_mod
    from repro.kernels.fedfa_agg import ops as agg_ops
    from repro.sharding import cohort as csh

    fl_q = dataclasses.replace(fl, update_dtype=dt)
    (index, m_real, mp, (masks, gates, gmaps, nd, cms_in, mal, bpad),
     g, _) = _mesh_inputs(cfg, fl_q, params, specs, batches, mesh)
    cb, co = csh.cohort_buffer_sharding(mesh), csh.cohort_sharding(mesh)
    state = round_mod.fresh_quant_state(index, mp, dt)
    xq, sc, eq, es = (jax.device_put(b, s)
                      for b, s in zip(state, (cb, co, cb, co)))
    fn = round_mod.make_flat_round(cfg, fl_q, index, any_malicious=False,
                                   mesh=mesh, m_real=m_real)
    keys = jax.random.split(jax.random.PRNGKey(0), mp)
    txt = fn.lower(g, xq, sc, eq, es, masks, gates, gmaps, nd, cms_in, mal,
                   bpad, keys).compile().as_text()
    counts = Counter(op.kind for op in hlo.collectives(txt))

    seg_id, _, _ = flat._segment_maps(index)
    ones_n = jnp.ones((index.n_padded,), jnp.float32)

    def acc(x_q, w, wtab):
        return agg_ops.accumulate_quant(x_q, w, wtab, jnp.asarray(seg_id),
                                        ones_n, use_kernel=True,
                                        interpret=True)

    jaxpr = jax.make_jaxpr(acc)(
        jnp.zeros((mp, index.n_padded), flat.update_dtype_of(dt)),
        jnp.ones((mp,), jnp.float32),
        jnp.ones((mp, index.n_segments), jnp.float32))
    report = round_mod.quantized_round_contract(index, mesh, rows=mp).check(
        hlo=txt, jaxpr=jaxpr, row_elems=mp * index.n_padded)
    return dict(counts), report


def _quant_section(cfg, fl, params, specs, batches, mesh, dts, m, rounds,
                   rec):
    """Bench + gate the quantized-admission round per dtype on the
    data-only mesh; fills ``rec['quantized'][dt]`` and returns overall ok.

    bytes_on_wire is the per-round admission payload a client uploads:
    f32 = n_padded*4 vs quantized = n_padded*itemsize + S*4 scales (S =
    segment count, S << N, so int8 lands just under 4x).  The int8
    reduction is gated >= 3.5x.  per_device_resident_bytes compares the
    f32 (m/D, N) cohort scratch against the quantized inter-round state —
    TWO pools (rows + error feedback) in the admitted dtype plus two
    (m/D, S) f32 scale tables — so the resident win is ~2x at int8, not
    4x; the 4x is on the wire.  The quantized_round_contract (zero
    all-gathers, five donated pools, peak budget, read-once fused
    dequantize) is gated per dtype."""
    import dataclasses

    import jax.numpy as jnp
    from repro.core import flat
    from repro.sharding import cohort as csh

    index = flat.get_index(params, pad_to=csh.pad_unit(mesh))
    d_sh = csh.data_shards(mesh)
    mp = m + csh.pad_rows(m, mesh)
    n, S = index.n_padded, index.n_segments
    wire_f32 = n * 4
    res_f32 = (mp // d_sh) * n * 4
    ok = True
    qsec = rec["quantized"] = {}
    for dt in dts:
        fl_q = dataclasses.replace(fl, update_dtype=dt)
        dt_q = _time_resident(cfg, fl_q, params, specs, batches, rounds,
                              mesh=mesh)
        counts, report = _quant_collectives(cfg, fl, params, specs, batches,
                                            mesh, dt)
        isz = jnp.dtype(flat.update_dtype_of(dt)).itemsize
        wire_q = n * isz + S * 4
        res_q = (mp // d_sh) * (2 * n * isz + 2 * S * 4)
        wire_ratio = wire_f32 / wire_q
        qsec[dt] = {
            "mean_s": round(dt_q / rounds, 5),
            "rounds_per_s": round(rounds / dt_q, 3),
            "collectives": counts,
            "all_gathers": counts.get("all-gather", 0),
            "bytes_on_wire_per_client": {
                "f32": wire_f32, dt: wire_q,
                "reduction": round(wire_ratio, 3)},
            "per_device_resident_bytes": {
                "f32_cohort_scratch": res_f32, f"{dt}_pools": res_q,
                "reduction": round(res_f32 / res_q, 3)},
            "contract": {"name": report.contract.name,
                         "ok": report.ok,
                         "peak_live_bytes_per_device":
                             report.measured.get(
                                 "peak_live_bytes_per_device"),
                         "violations": report.violations},
        }
        print(f"m={m:3d} quant {dt:>4s}  {qsec[dt]['rounds_per_s']:7.2f} "
              f"r/s  wire {wire_ratio:.2f}x  resident "
              f"{res_f32 / res_q:.2f}x  collectives {counts}", flush=True)
        if not report.ok:
            for v in report.violations:
                print(f"FAIL contract {report.contract.name} at m={m} "
                      f"dt={dt}: {v}", flush=True)
            ok = False
        if counts.get("all-gather", 0):
            print(f"FAIL: {counts['all-gather']} all-gather(s) in the "
                  f"quantized round at m={m} dt={dt}", flush=True)
            ok = False
        if dt == "int8" and wire_ratio < 3.5:
            print(f"FAIL: int8 bytes-on-wire reduction {wire_ratio:.2f}x "
                  f"< required 3.5x at m={m}", flush=True)
            ok = False
    return ok


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--cohorts", nargs="+", type=int, default=[4, 16])
    ap.add_argument("--rounds", type=int, default=5)
    ap.add_argument("--local-steps", type=int, default=1)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq-len", type=int, default=16)
    ap.add_argument("--model-shards", nargs="+", type=int, default=[1],
                    help="model-axis shard counts to bench; 1 = the PR 3 "
                         "data-only mesh, k > 1 = a (n_dev/k, k) "
                         "(data, model) mesh with reduce-scattered "
                         "aggregation and N/k resident slices per device")
    ap.add_argument("--update-dtype", nargs="*", choices=("bf16", "int8"),
                    default=None,
                    help="also bench the quantized round at these admission "
                         "dtypes on the data-only mesh (bare flag = both): "
                         "bytes-on-wire + per-device resident bytes per "
                         "dtype, quantized_round_contract gated, int8 "
                         "bytes-on-wire reduction gated >= 3.5x")
    ap.add_argument("--smoke", action="store_true",
                    help="m=4 only, 3 rounds — the tier-1 CI configuration")
    ap.add_argument("--min-ratio", type=float, default=None,
                    help="exit 1 if sharded/unsharded rounds-per-sec falls "
                         "below this (default: 0.75 on a single device, "
                         "structural checks only on multi-device)")
    ap.add_argument("--out", default=None,
                    help="output json (default: BENCH_shard.json, or "
                         "results/BENCH_shard_smoke.json with --smoke so CI "
                         "smoke runs don't clobber the checked-in anchor)")
    args = ap.parse_args()
    if args.smoke:
        args.cohorts, args.rounds = [4], 3
    if args.out is None:
        args.out = "results/BENCH_shard_smoke.json" if args.smoke \
            else "BENCH_shard.json"

    import jax
    from repro.launch.mesh import make_data_mesh, make_mesh_2d
    from repro.sharding import cohort as csh

    n_dev = jax.device_count()
    meshes = {}
    for ms in dict.fromkeys(args.model_shards):
        if n_dev % ms != 0:
            print(f"SKIP model-shards={ms}: {n_dev} devices not divisible")
            continue
        meshes[ms] = make_data_mesh() if ms == 1 \
            else make_mesh_2d(n_dev // ms, ms)
    if not meshes:
        print(f"no runnable mesh for --model-shards {args.model_shards} on "
              f"{n_dev} device(s)")
        sys.exit(1)
    min_ratio = args.min_ratio
    if min_ratio is None and n_dev == 1:
        # 1x1 mesh: sharding annotations must be ~free on the host path
        min_ratio = 0.75

    results = {"backend": jax.default_backend(), "n_devices": n_dev,
               "model_shards": sorted(meshes),
               "meshes": {f"ms{ms}": {ax: int(s)
                                      for ax, s in mesh.shape.items()}
                          for ms, mesh in meshes.items()},
               "config": {"rounds": args.rounds,
                          "local_steps": args.local_steps,
                          "batch": args.batch, "seq_len": args.seq_len},
               "schema_notes": SCHEMA_NOTES,
               "runs": {}}
    ok = True
    for m in args.cohorts:
        cfg, fl, params, specs, batches = _setup(
            m, args.local_steps, args.batch, args.seq_len)
        dt_un = _time_resident(cfg, fl, params, specs, batches, args.rounds,
                               mesh=None)
        rec = {"unsharded": {"mean_s": round(dt_un / args.rounds, 5),
                             "rounds_per_s": round(args.rounds / dt_un, 3)}}
        results["runs"][f"m{m}"] = rec
        for ms, mesh in meshes.items():
            dt_sh = _time_resident(cfg, fl, params, specs, batches,
                                   args.rounds, mesh=mesh)
            counts, full_gathers, psums, max_gather, report = _collectives(
                cfg, fl, params, specs, batches, mesh)
            n_ag, n_rs, big_ars = _agg_collectives(
                cfg, fl, params, specs, batches, mesh)
            from repro.core import flat
            index = flat.get_index(params, pad_to=csh.pad_unit(mesh))
            d_sh = csh.data_shards(mesh)
            mp = m + csh.pad_rows(m, mesh)
            ratio = dt_un / max(dt_sh, 1e-9)
            sub = {
                "mean_s": round(dt_sh / args.rounds, 5),
                "rounds_per_s": round(args.rounds / dt_sh, 3),
                "sharded_over_unsharded": round(ratio, 3),
                "collectives": counts,
                "full_cohort_all_gathers": full_gathers,
                "n_psum_reduces": psums,
                "max_all_gather_elems": max_gather,
                "agg_collectives": {"all_gathers": n_ag,
                                    "reduce_scatters": n_rs,
                                    "big_all_reduce_elems": big_ars},
                "per_device_bytes": {
                    "g_buf": index.n_padded // ms * 4,
                    "c_buf": (mp // d_sh) * (index.n_padded // ms) * 4,
                },
                "n_padded": index.n_padded,
                "contract": {"name": report.contract.name,
                             "ok": report.ok,
                             "peak_live_bytes_per_device":
                                 report.measured.get(
                                     "peak_live_bytes_per_device"),
                             "violations": report.violations},
            }
            rec[f"ms{ms}"] = sub
            print(f"m={m:3d} ms={ms}  unsharded "
                  f"{rec['unsharded']['rounds_per_s']:7.2f} r/s  sharded "
                  f"{sub['rounds_per_s']:7.2f} r/s  ratio {ratio:.2f}x  "
                  f"agg[ag={n_ag} rs={n_rs} ar={big_ars}]  "
                  f"collectives {counts}", flush=True)
            if not report.ok:
                # the declared round contract (collective caps, donation,
                # per-device peak-bytes budget) with blamed source lines
                for v in report.violations:
                    print(f"FAIL contract {report.contract.name} at m={m} "
                          f"ms={ms}: {v}", flush=True)
                ok = False
            if full_gathers:
                print(f"FAIL: {full_gathers} all-gather(s) materialize the "
                      f"full (m, N) cohort at m={m} ms={ms}", flush=True)
                ok = False
            if n_ag:
                print(f"FAIL: {n_ag} all-gather(s) in the aggregation path "
                      f"at m={m} ms={ms}", flush=True)
                ok = False
            if ms == 1 and n_dev > 1 and counts.get("all-gather", 0) > 0:
                # the data-only round has no legitimate all-gather at all; a
                # nonzero count means cohort data is being re-replicated
                # (the leaf-by-leaf top_k re-gather is each smaller than
                # m*N, so the full-cohort check alone would miss it)
                print(f"FAIL: {counts['all-gather']} all-gather(s) in the "
                      f"data-only sharded round at m={m} — cohort data is "
                      f"being re-replicated", flush=True)
                ok = False
            if ms == 1 and n_dev > 1 and psums < 1:
                print(f"FAIL: no N-sized all-reduce in the sharded round at "
                      f"m={m} — the (M', γ) reduction is not a per-shard "
                      f"partial sum + psum", flush=True)
                ok = False
            if ms > 1 and n_dev > 1:
                half = index.n_padded // ms
                from repro.kernels.fedfa_quantile.multilevel import \
                    histogram_elems
                hist = histogram_elems(max(1, mp // d_sh), index.n_segments)
                if n_rs != 0:
                    # ISSUE 9: the N axis splits EARLY — per-shard partial
                    # sums finish with N/n_model psums over ``data``; a
                    # reduce-scatter means an N-wide intermediate came back
                    print(f"FAIL: {n_rs} reduce-scatter(s) in the 2-D "
                          f"aggregation path at m={m} ms={ms} — the "
                          f"distributed two-stage path never widens to N",
                          flush=True)
                    ok = False
                if any(e != half and e > hist for e in big_ars):
                    print(f"FAIL: all-reduce volume above N/n_model at "
                          f"m={m} ms={ms}: {big_ars} (N/{ms} = {half}, "
                          f"histogram cap = {hist})", flush=True)
                    ok = False
                if max_gather > index.n_padded:
                    # GSPMD may re-layout TRAINING intermediates over the
                    # idle model axis (observed: a ~2-cohort-row gather at
                    # m=16); the gated invariant is the aggregation path
                    # (all_gathers == 0 above) + no FULL-cohort gather, so
                    # this is recorded but informational
                    print(f"note: training-side all-gather of "
                          f"{max_gather} elems (> N = {index.n_padded}) "
                          f"in the 2-D round at m={m} ms={ms}", flush=True)
            if min_ratio is not None and ms == 1 and ratio < min_ratio:
                # wall-clock is gated on the data-only mesh only: 2-D CPU
                # ratios are noisy/slow by construction (the gated 2-D
                # signal is the collective structure above)
                print(f"FAIL: sharded/unsharded ratio {ratio:.2f} < "
                      f"required {min_ratio:.2f} at m={m} ms={ms}",
                      flush=True)
                ok = False
        if args.update_dtype is not None and 1 in meshes:
            dts = list(args.update_dtype) or ["bf16", "int8"]
            qok = _quant_section(cfg, fl, params, specs, batches,
                                 meshes[1], dts, m, args.rounds, rec)
            ok = ok and qok

    out = args.out if os.path.isabs(args.out) else os.path.normpath(
        os.path.join(os.path.dirname(os.path.abspath(__file__)), "..",
                     args.out))
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w") as f:
        json.dump(results, f, indent=1)
    print(f"wrote {out}")
    if not ok:
        sys.exit(1)


if __name__ == "__main__":
    main()
