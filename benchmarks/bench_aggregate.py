"""Aggregation engine benchmark: tree (per-leaf scan) vs flat (fused
buffer) across cohort sizes and model sizes.

Emits ``BENCH_aggregate.json`` — mean/p50 wall time per (model, m, engine)
— so later PRs can track the perf trajectory.

  PYTHONPATH=src python benchmarks/bench_aggregate.py [--quick]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np


def _cohort(cfg, m, seed=0):
    import jax
    import jax.numpy as jnp
    from repro.models import model as model_mod
    from repro.models.masks import ClientArch, full_client, stack_masks

    g = model_mod.init_params(cfg, jax.random.PRNGKey(seed))
    pool = [ClientArch(0.25, (1, 1)), ClientArch(0.5, (2, 1)),
            ClientArch(1.0, (1, 2)), full_client(cfg)]
    archs = [pool[i % len(pool)] for i in range(m)]
    noise = 0.05 * jax.random.normal(
        jax.random.PRNGKey(seed + 1), (m,), jnp.float32)
    stacked = jax.tree.map(
        lambda x: x[None] + noise.reshape((m,) + (1,) * x.ndim)
        .astype(x.dtype), g)
    masks = stack_masks([a.masks(cfg) for a in archs])
    gates = jnp.stack([a.gates(cfg) for a in archs])
    gmaps = jnp.stack([a.graft(cfg) for a in archs])
    nd = jnp.asarray(np.arange(1, m + 1), jnp.float32)
    return g, stacked, masks, gates, gmaps, nd


def _time_engine(engine, cfg, args_, iters):
    import jax
    from repro.core import fedfa

    g, stacked, masks, gates, gmaps, nd = args_

    @jax.jit
    def run(g, s, mk, gt, gm, nd):
        return fedfa.aggregate(g, s, cfg, mk, gt, gm, nd,
                               graft=True, scale=True, engine=engine)

    out = run(g, stacked, masks, gates, gmaps, nd)      # compile + warm
    jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(run(g, stacked, masks, gates, gmaps, nd))
        ts.append(time.perf_counter() - t0)
    ts = np.asarray(ts)
    return dict(mean_s=round(float(ts.mean()), 5),
                p50_s=round(float(np.median(ts)), 5),
                iters=iters)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--models", nargs="+",
                    default=["smollm-135m", "tinyllama-1.1b"])
    ap.add_argument("--cohorts", nargs="+", type=int, default=[4, 16, 64])
    ap.add_argument("--iters", type=int, default=10)
    ap.add_argument("--quick", action="store_true",
                    help="one model, m in {4, 16}, fewer iters")
    ap.add_argument("--out", default="BENCH_aggregate.json")
    args = ap.parse_args()
    if args.quick:
        args.models, args.cohorts, args.iters = args.models[:1], [4, 16], 5

    import jax
    from repro.configs import get_arch

    results = {"backend": jax.default_backend(), "engines": ["tree", "flat"],
               "runs": {}}
    for name in args.models:
        cfg = get_arch(name).reduced().replace(n_layers=4, n_sections=2)
        for m in args.cohorts:
            cohort = _cohort(cfg, m)
            rec = {}
            for engine in ("tree", "flat"):
                rec[engine] = _time_engine(engine, cfg, cohort, args.iters)
            rec["flat_speedup"] = round(
                rec["tree"]["mean_s"] / max(rec["flat"]["mean_s"], 1e-9), 3)
            results["runs"][f"{name}/m{m}"] = rec
            print(f"{name} m={m:3d}  tree {rec['tree']['mean_s']*1e3:8.1f} ms"
                  f"  flat {rec['flat']['mean_s']*1e3:8.1f} ms"
                  f"  speedup {rec['flat_speedup']:.2f}x", flush=True)

    out = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..",
                       args.out) if not os.path.isabs(args.out) else args.out
    with open(os.path.normpath(out), "w") as f:
        json.dump(results, f, indent=1)
    print(f"wrote {os.path.normpath(out)}")


if __name__ == "__main__":
    main()
