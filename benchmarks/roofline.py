import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")
"""Roofline analysis (g): three terms per (arch x shape x mesh).

Methodology (EXPERIMENTS.md §Roofline):
  * XLA's HloCostAnalysis counts while-loop bodies ONCE (verified by probe:
    scan(10 matmuls) reports 1 matmul), so the full scanned program
    under-reports.  We therefore lower a PER-LAYER PROBE (one pattern unit,
    no scan, dense attention so the quadratic term is visible to XLA) and
    COMPOSE:  total_X = full_X + (A*U - 1) * unit_X + (A - 1) * trunk_X
    where U = layer units, A = grad-accum microbatches, X in {flops, bytes,
    collective_bytes};  full_X counts one unit + trunk + optimizer once.
  * compute term additionally cross-checked against the exact analytic
    matmul-level model in repro.launch.costs.
  * memory_analysis (buffer assignment) needs no correction — the dry-run's
    per-device peak is real.
"""
import argparse
import functools
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCHS, INPUT_SHAPES, get_arch
from repro.launch import steps as steps_mod
from repro.launch.costs import step_flops
from repro.launch.dryrun import (HW, _long_window, _shard, abstract_params,
                                 collective_bytes, model_flops)
from repro.launch.mesh import make_production_mesh
from repro.models import attention as attn_mod
from repro.models import model as model_mod
from repro.models.masks import full_masks
from repro.models.transformer import _stage_apply
from repro.sharding.specs import batch_axes, param_specs


_CALIB = None


def bytes_calibration() -> float:
    """XLA's 'bytes accessed' over-counts vs the streaming minimum (its
    tiling model re-counts operands); measure the factor on a plain matmul
    once and divide the memory term by it.  Recorded in every result."""
    global _CALIB
    if _CALIB is None:
        a = jax.ShapeDtypeStruct((4096, 4096), jnp.bfloat16)
        c = jax.jit(lambda x, y: x @ y).lower(a, a).compile()
        theory = 3 * 4096 * 4096 * 2
        _CALIB = float(c.cost_analysis()["bytes accessed"]) / theory
    return _CALIB


def _cost_of(lowered):
    c = lowered.compile()
    ca = c.cost_analysis()
    coll = collective_bytes(c.as_text())
    return dict(flops=float(ca.get("flops", 0.0)),
                bytes=float(ca.get("bytes accessed", 0.0)),
                coll=float(coll.get("total", 0)),
                coll_by_op={k: v for k, v in coll.items() if k != "total"})


def probe_unit(cfg, shape, mesh, multi_pod: bool) -> Dict[str, float]:
    """Lower ONE pattern-unit (unrolled, dense attention) on the mesh."""
    unit = cfg.pattern_unit
    probe_cfg = cfg.replace(n_layers=len(unit))
    window = _long_window(cfg, shape)
    win = window if window is not None else cfg.attn_window
    B = shape.global_batch
    A = cfg.grad_accum if shape.kind == "train" else 1
    Bm = max(B // A, 1)
    S = 1 if shape.kind == "decode" else shape.seq_len
    if cfg.vision is not None and shape.kind != "decode":
        S = shape.seq_len  # residual stream carries patches+text
    m = full_masks(cfg)
    b = batch_axes(multi_pod)
    baxes = b if len(b) > 1 else b[0]

    stage_abs = jax.tree.map(
        lambda x: x, jax.eval_shape(
            lambda: model_mod.init_params(probe_cfg, jax.random.PRNGKey(0),
                                          jnp.bfloat16))["stages"][0])
    sspec = param_specs(probe_cfg)["stages"][0]
    x_abs = jax.ShapeDtypeStruct((Bm, S, cfg.d_model), jnp.bfloat16)
    gates = jnp.ones((1,), jnp.float32)
    pos_abs = jnp.arange(S)[None]

    old_unroll = attn_mod._FORCE_UNROLL
    attn_mod._FORCE_UNROLL = True          # unrolled blocks: XLA-countable
    try:
        with mesh:
            xsh = _shard(mesh, P(baxes, None, None), x_abs)
            psh = _shard(mesh, sspec, stage_abs)
            if shape.kind == "train":
                def fn(sp, x):
                    out, _, _ = _stage_apply(sp, unit, x, probe_cfg, m,
                                             gates=gates, positions=pos_abs,
                                             window=win, remat=False)
                    return jnp.sum(out.astype(jnp.float32))
                g = jax.jit(jax.grad(fn, argnums=(0, 1)),
                            in_shardings=(psh, xsh))
                lowered = g.lower(stage_abs, x_abs)
            elif shape.kind == "prefill":
                caches = jax.eval_shape(functools.partial(
                    model_mod.init_caches, None, probe_cfg, Bm,
                    shape.seq_len, window=win, dtype=jnp.bfloat16))
                from repro.sharding.specs import cache_specs
                cspec = cache_specs(probe_cfg, multi_pod)[0]
                csh = _shard(mesh, cspec, caches[0])

                def fn(sp, c0, x):
                    out, nc, _ = _stage_apply(sp, unit, x, probe_cfg, m,
                                              gates=gates, positions=pos_abs,
                                              window=win, caches=c0,
                                              remat=False)
                    return out, nc
                lowered = jax.jit(fn, in_shardings=(psh, csh, xsh)).lower(
                    stage_abs, caches[0], x_abs)
            else:  # decode
                cap = min(shape.seq_len, win) if win else shape.seq_len
                caches = jax.eval_shape(functools.partial(
                    model_mod.init_caches, None, probe_cfg, Bm, cap,
                    window=win, dtype=jnp.bfloat16))
                from repro.sharding.specs import cache_specs, sanitize_specs
                cspec = cache_specs(probe_cfg, multi_pod)[0]
                csh = _shard(mesh, cspec, caches[0])

                def fn(sp, c0, x):
                    pos1 = jnp.full((Bm, 1), shape.seq_len - 1, jnp.int32)
                    out, nc, _ = _stage_apply(sp, unit, x, probe_cfg, m,
                                              gates=gates, positions=pos1,
                                              window=win, caches=c0,
                                              decode=True, remat=False)
                    return out, nc
                x1 = jax.ShapeDtypeStruct((Bm, 1, cfg.d_model), jnp.bfloat16)
                lowered = jax.jit(fn, in_shardings=(psh, csh, xsh)).lower(
                    stage_abs, caches[0], x1)
            return _cost_of(lowered)
    finally:
        attn_mod._FORCE_UNROLL = old_unroll


def probe_trunk(cfg, shape, mesh, multi_pod: bool) -> Dict[str, float]:
    """Embed + LM-head (+grad) cost — the non-layer part of a microbatch."""
    B = shape.global_batch
    A = cfg.grad_accum if shape.kind == "train" else 1
    Bm = max(B // A, 1)
    S = 1 if shape.kind == "decode" else shape.seq_len
    V, D = cfg.padded_vocab, cfg.d_model
    b = batch_axes(multi_pod)
    baxes = b if len(b) > 1 else b[0]
    f = "data" if cfg.fsdp else None
    emb_abs = jax.ShapeDtypeStruct((V, D), jnp.bfloat16)
    tok_abs = jax.ShapeDtypeStruct((Bm, S), jnp.int32)
    from repro.sharding.specs import sanitize_specs
    with mesh:
        esh = _shard(mesh, P("model", f), emb_abs)
        tsh = _shard(mesh, P(baxes, None), tok_abs)

        def fn(emb, tok):
            x = emb[tok]
            logits = x @ emb.T
            if shape.kind == "train":
                return jnp.sum(jax.nn.log_softmax(
                    logits.astype(jnp.float32), -1))
            return logits

        if shape.kind == "train":
            g = jax.jit(jax.grad(fn), in_shardings=(esh, tsh))
            lowered = g.lower(emb_abs, tok_abs)
        else:
            lowered = jax.jit(fn, in_shardings=(esh, tsh)).lower(
                emb_abs, tok_abs)
        return _cost_of(lowered)


def analyse(arch: str, shape_name: str, *, multi_pod: bool = False,
            dryrun_dir: str = "results/dryrun") -> Dict[str, Any]:
    cfg = get_arch(arch)
    shape = INPUT_SHAPES[shape_name]
    meshname = "2x16x16" if multi_pod else "16x16"
    tag = f"{arch}_{shape_name}_{meshname}"
    full_path = os.path.join(dryrun_dir, tag + ".json")
    rec: Dict[str, Any] = dict(arch=arch, shape=shape_name, mesh=meshname)
    if not os.path.exists(full_path):
        rec["status"] = "missing-dryrun"
        return rec
    full = json.load(open(full_path))
    if full["status"] == "skipped":
        return dict(rec, status="skipped", reason=full.get("reason"))
    if full["status"] != "ok":
        return dict(rec, status="dryrun-error")

    mesh = make_production_mesh(multi_pod=multi_pod)
    unit = probe_unit(cfg, shape, mesh, multi_pod)
    trunk = probe_trunk(cfg, shape, mesh, multi_pod)
    U = cfg.n_layers / len(cfg.pattern_unit)
    A = cfg.grad_accum if shape.kind == "train" else 1

    def compose(key):
        f = float(full.get("cost", {}).get(
            {"flops": "flops", "bytes": "bytes accessed"}.get(key, key), 0)
            if key != "coll" else full["collectives"].get("total", 0))
        return f + (A * U - 1) * unit[key] + (A - 1) * trunk[key]

    window = _long_window(cfg, shape)
    flops_analytic = step_flops(cfg, shape, window=window)
    flops_hlo = compose("flops") * (1 if True else 1)
    bytes_hlo = compose("bytes")
    coll_hlo = compose("coll")
    chips = mesh.devices.size
    calib = bytes_calibration()
    # probe/full values are per-device
    terms = dict(
        compute_s=flops_analytic / (chips * HW["peak_flops"]),
        compute_s_hlo=flops_hlo / HW["peak_flops"],
        memory_s=bytes_hlo / calib / HW["hbm_bw"],
        memory_s_raw=bytes_hlo / HW["hbm_bw"],
        collective_s=coll_hlo / HW["ici_bw"],
        bytes_calibration=calib,
    )
    core = {k: terms[k] for k in ("compute_s", "memory_s", "collective_s")}
    mf = model_flops(cfg, shape)
    rec.update(
        status="ok",
        probe_unit=unit, probe_trunk=trunk, layer_units=U, grad_accum=A,
        flops_analytic=flops_analytic, flops_hlo_per_dev=flops_hlo,
        bytes_hlo_per_dev=bytes_hlo, coll_hlo_per_dev=coll_hlo,
        terms=terms,
        bottleneck=max(core, key=core.get),
        model_flops=mf,
        useful_flops_ratio=mf / flops_analytic if flops_analytic else None,
        peak_bytes_per_dev=full.get("memory", {}).get("peak_bytes"),
        what_would_move_it=_advice(cfg, shape, core),
    )
    return rec


def _advice(cfg, shape, terms) -> str:
    b = max(terms, key=terms.get)
    if b == "collective_s":
        if cfg.fsdp:
            return ("collective-bound: FSDP all-gathers dominate; overlap "
                    "weight gathering with compute or drop fsdp for this "
                    "shape (weights fit when sharded over model only)")
        return ("collective-bound: tensor-parallel all-reduces dominate; "
                "fewer model-axis shards or activation-sharded "
                "(sequence-parallel) norms would cut them")
    if b == "memory_s":
        if shape.kind == "decode":
            return ("HBM-bound: decode reads all weights + cache per token; "
                    "batch more requests per step or quantize the cache")
        return ("HBM-bound: increase arithmetic intensity (fuse attention "
                "via the Pallas kernel, larger microbatches, bf16 "
                "accumulation where safe)")
    return "compute-bound: near roofline; only algorithmic wins remain"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default="results/roofline")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    archs = [args.arch] if args.arch else \
        [a for a in ARCHS if a != "fedfa-paper-transformer"]
    shapes = [args.shape] if args.shape else list(INPUT_SHAPES)
    for a in archs:
        for s in shapes:
            tag = f"{a}_{s}_{'2x16x16' if args.multi_pod else '16x16'}"
            path = os.path.join(args.out, tag + ".json")
            if os.path.exists(path):
                print(f"[skip] {tag}")
                continue
            t0 = time.time()
            try:
                rec = analyse(a, s, multi_pod=args.multi_pod)
            except Exception as e:
                rec = dict(arch=a, shape=s, status="error",
                           error=f"{type(e).__name__}: {e}",
                           trace=traceback.format_exc()[-1500:])
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
            if rec["status"] == "ok":
                t = rec["terms"]
                print(f"{tag:45s} comp={t['compute_s']*1e3:8.2f}ms "
                      f"mem={t['memory_s']*1e3:8.2f}ms "
                      f"coll={t['collective_s']*1e3:8.2f}ms "
                      f"-> {rec['bottleneck']} ({time.time()-t0:.0f}s)",
                      flush=True)
            else:
                print(f"{tag:45s} {rec['status']}: {rec.get('error','')[:120]}",
                      flush=True)


if __name__ == "__main__":
    main()
