"""Render EXPERIMENTS.md tables from results/dryrun and results/roofline."""
from __future__ import annotations

import glob
import json
import os
import sys

ARCH_ORDER = ["minicpm-2b", "smollm-135m", "arctic-480b", "recurrentgemma-2b",
              "mamba2-130m", "tinyllama-1.1b", "phi3.5-moe-42b-a6.6b",
              "internvl2-76b", "codeqwen1.5-7b", "whisper-base"]
SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def _gb(x):
    return f"{x/2**30:.2f}" if isinstance(x, (int, float)) else "-"


def _ms(x):
    return f"{x*1e3:.2f}" if isinstance(x, (int, float)) else "-"


def dryrun_table(mesh: str, d: str = "results/dryrun") -> str:
    rows = [f"| arch | shape | status | peak GB/dev | HLO GFLOP/dev | "
            f"coll MB/dev | compile s |",
            "|---|---|---|---|---|---|---|"]
    for a in ARCH_ORDER:
        for s in SHAPES:
            f = os.path.join(d, f"{a}_{s}_{mesh}.json")
            if not os.path.exists(f):
                continue
            r = json.load(open(f))
            if r["status"] == "skipped":
                rows.append(f"| {a} | {s} | skipped (see DESIGN.md) | - | - | - | - |")
                continue
            if r["status"] != "ok":
                rows.append(f"| {a} | {s} | ERROR | - | - | - | - |")
                continue
            mem = r["memory"].get("peak_bytes")
            fl = r.get("cost", {}).get("flops", 0)
            co = r["collectives"].get("total", 0)
            rows.append(
                f"| {a} | {s} | ok | {_gb(mem)} | {fl/1e9:.1f} | "
                f"{co/2**20:.1f} | {r['lower_compile_s']} |")
    return "\n".join(rows)


def roofline_table(mesh: str = "16x16", d: str = "results/roofline") -> str:
    rows = ["| arch | shape | compute ms | memory ms | collective ms | "
            "bottleneck | MODEL_FLOPs/HLO | note |",
            "|---|---|---|---|---|---|---|---|"]
    for a in ARCH_ORDER:
        for s in SHAPES:
            f = os.path.join(d, f"{a}_{s}_{mesh}.json")
            if not os.path.exists(f):
                continue
            r = json.load(open(f))
            if r["status"] != "ok":
                rows.append(f"| {a} | {s} | - | - | - | {r['status']} | - | - |")
                continue
            t = r["terms"]
            ratio = r.get("useful_flops_ratio")
            rows.append(
                f"| {a} | {s} | {_ms(t['compute_s'])} | {_ms(t['memory_s'])} "
                f"| {_ms(t['collective_s'])} | {r['bottleneck'].replace('_s','')} "
                f"| {ratio:.2f} | {r['what_would_move_it'][:60]} |")
    return "\n".join(rows)


if __name__ == "__main__":
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    if which in ("all", "dryrun"):
        print("### 16x16\n")
        print(dryrun_table("16x16"))
        print("\n### 2x16x16\n")
        print(dryrun_table("2x16x16"))
    if which in ("all", "roofline"):
        print("\n### roofline\n")
        print(roofline_table())
