"""Async engine benchmark: bounded-staleness merges vs synchronous rounds.

Drives the async engine (``repro.core.async_round``) and the synchronous
resident driver over the SAME trace-driven client stream — per-client
latencies hashed from a ``repro.sim.ClientPopulation`` device-class fleet
(lognormal, heavily skewed: the mobile tail's median is 30x the servers')
— and reports throughput in SIMULATED time, the deterministic trace-derived
metric the gate rides on:

  * sync: a round ends when its slowest cohort member returns, so round r
    costs ``max(latency over the round's m clients)`` simulated seconds;
  * async: a merge fires on ``merge_k`` arrivals (bounded staleness), so
    the engine's clock after R merges IS the async cost of R global
    updates.

``ratio = sync_rounds_per_sim_s / async_merges_per_sim_s`` — gated >= 1.3x
under ``--min-ratio`` (CI smoke).  Host wall-clock for both drivers is
recorded as well but NOT gated (CPU wall time is noisy and both drivers
run the same jitted training/aggregation programs).  The run also gates
the structural invariants: parity mode bit-equal to ``run_rounds``, and
the declared admit + merge contracts on the freshly lowered programs —
ZERO all-gathers in both (the admit is a slot-order select since PR 8),
materialized donation, and the per-device peak-live-bytes budgets (when
>= 2 devices are present — CI forces 4).  Emits ``BENCH_async.json`` (or
``results/BENCH_async_smoke.json`` with ``--smoke``).

  PYTHONPATH=src python benchmarks/bench_async.py [--smoke] [--min-ratio X]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np


def _setup(m, local_steps, batch, seq_len, seed=0):
    import jax
    import jax.numpy as jnp
    from repro.configs import get_arch
    from repro.core.server import FLConfig, make_client_specs
    from repro.data import partition as part_mod
    from repro.data import pipeline, synthetic
    from repro.launch.train import client_arch_pool
    from repro.models import model as model_mod

    n_classes = 10
    cfg = get_arch("smollm-135m").reduced().replace(
        n_layers=4, n_sections=2, vocab_size=64, tie_embeddings=False)
    params = model_mod.init_params(cfg, jax.random.PRNGKey(seed))
    specs = make_client_specs(cfg, m, archs=client_arch_pool(cfg, "width"),
                              seed=seed)
    parts = part_mod.iid_partition(m, n_classes, seed=seed)
    profiles = synthetic.make_class_profiles(n_classes, cfg.vocab_size,
                                             seed=seed)

    def data_fn(r):
        b = pipeline.round_batches_cls(
            parts, list(range(m)), n_classes, cfg.vocab_size,
            local_steps=local_steps, batch=batch, seq_len=seq_len,
            profiles=profiles, seed=100 + r)
        return specs, {k: jnp.asarray(v) for k, v in b.items()}

    fl = FLConfig(local_steps=local_steps, lr=0.05, strategy="fedfa",
                  task="cls", agg_engine="flat")
    return cfg, fl, params, specs, data_fn


def _trace_latency_fn(seed=0, n_clients=10_000):
    """Deterministic per-stream-client latency from the hashed device-class
    population — the skewed trace both drivers are measured against."""
    from repro.sim import ClientPopulation
    pop = ClientPopulation(n_clients, seed=seed)

    def lat(i: int) -> float:
        return float(pop.latency(np.asarray([i % n_clients]),
                                 nonce=i // n_clients)[0])
    return lat


def _check_parity(cfg, fl, params, data_fn, m, rounds=2):
    """Bit-equality gate: parity-mode async == run_rounds."""
    import jax
    from repro.core.async_round import AsyncConfig, run_async
    from repro.core.round import run_rounds
    from repro.sim import ParitySource

    key = jax.random.PRNGKey(1)
    p_sync, l_sync = run_rounds(params, cfg, fl, rounds, data_fn, key,
                                eval_every=0)
    p_async, l_async = run_async(params, cfg, fl, rounds,
                                 ParitySource(data_fn), key,
                                 acfg=AsyncConfig.parity(m), eval_every=0)
    if l_sync != l_async:
        return False
    return all(bool((np.asarray(a) == np.asarray(b)).all())
               for a, b in zip(jax.tree.leaves(p_sync),
                               jax.tree.leaves(p_async)))


def _async_contract_reports(cfg, fl, params, specs, data_fn, rows):
    """Lower BOTH async programs (admit + bounded-staleness merge) on the
    bench's own shapes and evaluate their declared contracts — zero
    all-gathers (the admit is a slot-order select, the merge a partial-sum
    aggregation), materialized donation, per-device peak-bytes budgets.
    Needs a multi-device backend for the collectives to exist; returns
    None on one device."""
    import jax
    import jax.numpy as jnp
    if jax.device_count() < 2:
        return None
    from repro.core import async_round, flat
    from repro.core.server import default_class_masks, stack_runtimes
    from repro.launch.mesh import make_data_mesh
    from repro.sharding import cohort as csh

    mesh = make_data_mesh()
    index = flat.get_index(params, pad_to=csh.pad_unit(mesh))
    row_specs = (specs * rows)[:rows]
    _, batches = data_fn(0)
    bpad = jax.tree.map(
        lambda a: jnp.concatenate(
            [a] + [a[:1]] * (rows - a.shape[0]))[:rows], batches)
    masks, gates, gmaps, _, cms, mal = stack_runtimes(cfg, row_specs)
    cms_in = default_class_masks(cms, cfg, fl, rows)
    g = jax.device_put(flat.flatten(index, params),
                       csh.global_sharding(mesh))
    c = jax.device_put(jnp.zeros((rows, index.n_padded), jnp.float32),
                       csh.cohort_sharding(mesh))
    fl_k = fl.__class__(**{**fl.__dict__, "use_kernel": True,
                           "interpret": True})
    keys = jax.random.split(jax.random.PRNGKey(0), rows)
    written = jnp.ones((rows,), dtype=jnp.int32)
    g_rep = jax.device_put(flat.flatten(index, params),
                           csh.replicated(mesh))
    fn_a = async_round.make_admit_program(cfg, fl_k, index,
                                          any_malicious=False, mesh=mesh,
                                          rows=rows)
    txt_a = fn_a.lower(g_rep, c, masks, gates, gmaps, cms_in, mal, bpad,
                       keys, written).compile().as_text()
    admit = async_round.admit_contract(index, mesh, rows=rows) \
        .check(hlo=txt_a)
    w = jnp.arange(rows, dtype=jnp.float32)
    fn_m = async_round.make_merge_program(cfg, fl_k, index, mesh=mesh,
                                          rows=rows)
    txt_m = fn_m.lower(g, c, masks, gates, gmaps, w).compile().as_text()
    merge = async_round.merge_contract(index, mesh, rows=rows) \
        .check(hlo=txt_m)
    return admit, merge


def _run_async_traced(cfg, fl, params, data_fn, lat, m, merges,
                      merge_k, staleness_max):
    """(sim_time, merged_rows, wall_s) for R bounded-staleness merges over
    the traced stream."""
    import jax
    from repro.core import flat
    from repro.core.async_round import AsyncConfig, AsyncEngine
    from repro.sim import TraceSource

    acfg = AsyncConfig(capacity=m, merge_k=merge_k,
                       staleness_max=staleness_max)
    index = flat.get_index(params)
    eng = AsyncEngine(flat.flatten(index, params), cfg, fl, index,
                      TraceSource(data_fn, lat), jax.random.PRNGKey(1),
                      acfg=acfg)
    while eng.merges < 1:            # compile/warm outside the timed window
        eng.step()
    t0 = time.perf_counter()
    warm_now, warm_rows = eng.now, eng.merged_rows
    while eng.merges < merges + 1:
        eng.step()
    jax.block_until_ready(eng.g_buf)
    wall = time.perf_counter() - t0
    return eng.now - warm_now, eng.merged_rows - warm_rows, wall


def _run_sync_traced(cfg, fl, params, data_fn, lat, m, rounds):
    """(sim_time, wall_s) for R synchronous rounds over the same stream:
    round r consumes stream clients [r*m, (r+1)*m) and costs their max
    latency in simulated time."""
    import jax
    from repro.core import flat
    from repro.core.round import ResidentDriver

    sim = sum(max(lat(r * m + i) for i in range(m))
              for r in range(rounds))
    index = flat.get_index(params)
    driver = ResidentDriver(cfg, fl, index, mesh=None)
    key = jax.random.PRNGKey(1)
    specs, batches = data_fn(0)
    g_buf = flat.flatten(index, params)
    g_buf, _ = driver.round(g_buf, specs, batches,
                            jax.random.fold_in(key, 0))   # compile + warm
    jax.block_until_ready(g_buf)
    t0 = time.perf_counter()
    for r in range(1, rounds + 1):
        specs, batches = data_fn(r)
        g_buf, _ = driver.round(g_buf, specs, batches,
                                jax.random.fold_in(key, r))
    jax.block_until_ready(g_buf)
    return sim, time.perf_counter() - t0


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--cohorts", nargs="+", type=int, default=[8],
                    help="pool capacity / sync cohort size m")
    ap.add_argument("--merges", type=int, default=8,
                    help="timed merges (async) / rounds (sync)")
    ap.add_argument("--merge-k", type=int, default=0,
                    help="async merge threshold (0 = m // 2)")
    ap.add_argument("--staleness-max", type=int, default=4)
    ap.add_argument("--local-steps", type=int, default=1)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq-len", type=int, default=16)
    ap.add_argument("--smoke", action="store_true",
                    help="m=4 only, 4 merges — the tier-1 CI configuration")
    ap.add_argument("--min-ratio", type=float, default=None,
                    help="exit 1 if async/sync simulated rounds-per-second "
                         "falls below this for any m")
    ap.add_argument("--out", default=None,
                    help="output json (default: BENCH_async.json, or "
                         "results/BENCH_async_smoke.json with --smoke so CI "
                         "smoke runs don't clobber the checked-in anchor)")
    args = ap.parse_args()
    if args.smoke:
        args.cohorts, args.merges = [4], 4
    if args.out is None:
        args.out = "results/BENCH_async_smoke.json" if args.smoke \
            else "BENCH_async.json"

    import jax

    results = {"backend": jax.default_backend(),
               "devices": jax.device_count(),
               "config": {"merges": args.merges,
                          "staleness_max": args.staleness_max,
                          "local_steps": args.local_steps,
                          "batch": args.batch, "seq_len": args.seq_len,
                          "trace": "ClientPopulation(10k, seed=0) hashed "
                                   "device-class lognormal latencies"},
               "runs": {}}
    ok = True
    lat = _trace_latency_fn()
    for m in args.cohorts:
        merge_k = args.merge_k if args.merge_k > 0 else max(1, m // 2)
        cfg, fl, params, specs, data_fn = _setup(
            m, args.local_steps, args.batch, args.seq_len)
        parity = _check_parity(cfg, fl, params, data_fn, m)
        if not parity:
            print(f"FAIL: parity mode not bit-equal to run_rounds at m={m}",
                  flush=True)
            ok = False
        sync_sim, sync_wall = _run_sync_traced(
            cfg, fl, params, data_fn, lat, m, args.merges)
        async_sim, async_rows, async_wall = _run_async_traced(
            cfg, fl, params, data_fn, lat, m, args.merges,
            merge_k, args.staleness_max)
        reports = _async_contract_reports(
            cfg, fl, params, specs, data_fn,
            rows=m + (-m) % jax.device_count())
        gathers = None if reports is None else \
            reports[1].measured.get("all_gathers")
        sync_rps = args.merges / sync_sim
        async_rps = args.merges / async_sim
        rec = {
            "merge_k": merge_k,
            "parity_bit_equal": parity,
            "sim": {"sync_rounds_per_s": round(sync_rps, 5),
                    "async_merges_per_s": round(async_rps, 5),
                    "ratio": round(async_rps / sync_rps, 3),
                    "sync_clients_per_s": round(
                        args.merges * m / sync_sim, 5),
                    "async_clients_per_s": round(
                        async_rows / async_sim, 5)},
            "wall_s_not_gated": {"sync": round(sync_wall, 3),
                                 "async": round(async_wall, 3)},
            "merge_all_gathers": gathers,
            "contracts": None if reports is None else {
                r.contract.name: {
                    "ok": r.ok,
                    "peak_live_bytes_per_device":
                        r.measured.get("peak_live_bytes_per_device"),
                    "violations": r.violations}
                for r in reports},
        }
        results["runs"][f"m{m}"] = rec
        print(f"m={m:3d}  sim sync {sync_rps:8.4f} r/s  "
              f"async {async_rps:8.4f} m/s  ratio {rec['sim']['ratio']:.2f}x"
              f"  parity={'OK' if parity else 'FAIL'}"
              f"  all-gathers={gathers}", flush=True)
        if gathers is not None and gathers != 0:
            print(f"FAIL: {gathers} all-gather(s) in the merge aggregation "
                  f"at m={m}", flush=True)
            ok = False
        if reports is not None:
            for r in reports:
                if not r.ok:
                    # declared admit/merge contracts: 0 all-gathers,
                    # donation, peak-bytes budget — violations carry the
                    # blamed source line that introduced each collective
                    for v in r.violations:
                        print(f"FAIL contract {r.contract.name} at m={m}: "
                              f"{v}", flush=True)
                    ok = False
        if args.min_ratio is not None \
                and rec["sim"]["ratio"] < args.min_ratio:
            print(f"FAIL: async/sync ratio {rec['sim']['ratio']:.2f}x "
                  f"< required {args.min_ratio:.2f}x at m={m}", flush=True)
            ok = False

    out = args.out if os.path.isabs(args.out) else os.path.normpath(
        os.path.join(os.path.dirname(os.path.abspath(__file__)), "..",
                     args.out))
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w") as f:
        json.dump(results, f, indent=1)
    print(f"wrote {out}")
    if not ok:
        sys.exit(1)


if __name__ == "__main__":
    main()
