"""FL round-path benchmark: resident driver vs per-round dispatch.

The resident driver (``repro.core.round``) runs the whole round — vmapped
local training + flat aggregation — as one jitted program over donated
(N,)/(m, N) buffers; the per-round path re-stacks runtimes and eagerly
dispatches ``server.fl_round`` every round (what ``run_fl`` did before the
resident driver).  Emits ``BENCH_round.json`` — rounds/sec per (m, driver)
— the perf trajectory anchor for the round path.

  PYTHONPATH=src python benchmarks/bench_round.py [--smoke] [--min-speedup X]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np


def _setup(m, local_steps, batch, seq_len, seed=0):
    import jax
    import jax.numpy as jnp
    from repro.configs import get_arch
    from repro.core.server import FLConfig, make_client_specs
    from repro.data import partition as part_mod
    from repro.data import pipeline, synthetic
    from repro.launch.train import client_arch_pool
    from repro.models import model as model_mod

    n_classes = 10
    cfg = get_arch("smollm-135m").reduced().replace(
        n_layers=4, n_sections=2, vocab_size=64, tie_embeddings=False)
    params = model_mod.init_params(cfg, jax.random.PRNGKey(seed))
    specs = make_client_specs(cfg, m, archs=client_arch_pool(cfg, "width"),
                              seed=seed)
    parts = part_mod.iid_partition(m, n_classes, seed=seed)
    profiles = synthetic.make_class_profiles(n_classes, cfg.vocab_size,
                                             seed=seed)
    batches_np = pipeline.round_batches_cls(
        parts, list(range(m)), n_classes, cfg.vocab_size,
        local_steps=local_steps, batch=batch, seq_len=seq_len,
        profiles=profiles, seed=seed)
    batches = {k: jnp.asarray(v) for k, v in batches_np.items()}
    fl = FLConfig(local_steps=local_steps, lr=0.05, strategy="fedfa",
                  task="cls", agg_engine="flat")
    return cfg, fl, params, specs, batches


def _time_per_round(cfg, fl, params, specs, batches, rounds):
    import jax
    from repro.core.server import fl_round

    key = jax.random.PRNGKey(1)
    p, _ = fl_round(params, cfg, fl, specs, batches,
                    jax.random.fold_in(key, 0))       # warm dispatch caches
    jax.block_until_ready(jax.tree.leaves(p)[0])
    p = params
    t0 = time.perf_counter()
    for r in range(rounds):
        p, loss = fl_round(p, cfg, fl, specs, batches,
                           jax.random.fold_in(key, r))
    jax.block_until_ready(jax.tree.leaves(p)[0])
    return time.perf_counter() - t0


def _time_resident(cfg, fl, params, specs, batches, rounds, mesh=None):
    import jax
    from repro.core import flat
    from repro.core.round import ResidentDriver

    key = jax.random.PRNGKey(1)
    index = flat.get_index(params)
    driver = ResidentDriver(cfg, fl, index, mesh=mesh)
    g_buf = flat.flatten(index, params)
    g_buf, _ = driver.round(g_buf, specs, batches,
                            jax.random.fold_in(key, 0))  # compile + warm
    jax.block_until_ready(g_buf)
    t0 = time.perf_counter()
    for r in range(1, rounds + 1):
        g_buf, loss = driver.round(g_buf, specs, batches,
                                   jax.random.fold_in(key, r))
    jax.block_until_ready(g_buf)
    return time.perf_counter() - t0


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--cohorts", nargs="+", type=int, default=[4, 16, 64])
    ap.add_argument("--rounds", type=int, default=5,
                    help="timed rounds per (m, driver)")
    ap.add_argument("--local-steps", type=int, default=1)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq-len", type=int, default=16)
    ap.add_argument("--smoke", action="store_true",
                    help="m=4 only, 3 rounds — the tier-1 CI configuration")
    ap.add_argument("--min-speedup", type=float, default=None,
                    help="exit 1 if resident/per-round rounds/sec falls "
                         "below this for any cohort size")
    ap.add_argument("--out", default=None,
                    help="output json (default: BENCH_round.json, or "
                         "results/BENCH_round_smoke.json with --smoke so CI "
                         "smoke runs don't clobber the checked-in anchor)")
    args = ap.parse_args()
    if args.smoke:
        args.cohorts, args.rounds = [4], 3
    if args.out is None:
        args.out = "results/BENCH_round_smoke.json" if args.smoke \
            else "BENCH_round.json"

    import jax

    results = {"backend": jax.default_backend(),
               "drivers": ["per_round", "resident"],
               "config": {"rounds": args.rounds, "local_steps": args.local_steps,
                          "batch": args.batch, "seq_len": args.seq_len},
               "runs": {}}
    ok = True
    for m in args.cohorts:
        cfg, fl, params, specs, batches = _setup(
            m, args.local_steps, args.batch, args.seq_len)
        dt_pr = _time_per_round(cfg, fl, params, specs, batches, args.rounds)
        dt_res = _time_resident(cfg, fl, params, specs, batches, args.rounds)
        rec = {
            "per_round": {"mean_s": round(dt_pr / args.rounds, 5),
                          "rounds_per_s": round(args.rounds / dt_pr, 3)},
            "resident": {"mean_s": round(dt_res / args.rounds, 5),
                         "rounds_per_s": round(args.rounds / dt_res, 3)},
            "resident_speedup": round(dt_pr / max(dt_res, 1e-9), 3),
        }
        results["runs"][f"m{m}"] = rec
        print(f"m={m:3d}  per-round {rec['per_round']['rounds_per_s']:7.2f} r/s"
              f"  resident {rec['resident']['rounds_per_s']:7.2f} r/s"
              f"  speedup {rec['resident_speedup']:.2f}x", flush=True)
        if args.min_speedup is not None \
                and rec["resident_speedup"] < args.min_speedup:
            print(f"FAIL: resident speedup {rec['resident_speedup']:.2f}x "
                  f"< required {args.min_speedup:.2f}x at m={m}", flush=True)
            ok = False

    out = args.out if os.path.isabs(args.out) else os.path.normpath(
        os.path.join(os.path.dirname(os.path.abspath(__file__)), "..",
                     args.out))
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w") as f:
        json.dump(results, f, indent=1)
    print(f"wrote {out}")
    if not ok:
        sys.exit(1)


if __name__ == "__main__":
    main()
