"""Table 1 analog: global/local test accuracy for FedFA vs FlexiFed /
HeteroFL / NeFL across depth / width / both flexibility, IID and non-IID,
clean and attacked (lambda=20, 20% malicious, attackers on the largest
architecture).  Synthetic classification stands in for CIFAR/FMNIST
(offline container; DESIGN.md).
"""
from __future__ import annotations

import json
import os
import time

MODES = [("depth", "flexifed"), ("width", "heterofl"), ("both", "nefl")]


def run(quick: bool = True, out: str = "results/table1.json",
        seed: int = 0, reuse: bool = True) -> dict:
    # the full 24-cell grid takes ~1 h on this single-core container; the
    # harness reuses a completed grid (delete results/table1.json or pass
    # reuse=False to force a fresh run).
    if reuse and os.path.exists(out):
        res = json.load(open(out))
        if sum(1 for k in res if "/drop/" in k) == 12:
            print(f"[table1] reusing completed grid from {out}")
            return res
    from repro.launch.train import run_fl
    rounds = 10 if quick else 40
    n_clients = 8 if quick else 24
    res = {}
    for mode, baseline in MODES:
        for dist in (["iid", "noniid"] if not quick else ["iid", "noniid"]):
            for attack in ["clean", "attacked"]:
                for strat in ["fedfa", baseline]:
                    tag = f"{mode}/{dist}/{attack}/{strat}"
                    t0 = time.time()
                    h = run_fl(
                        "smollm-135m", rounds, n_clients, strategy=strat,
                        arch_mode=mode, noniid=(dist == "noniid"),
                        malicious_frac=0.2 if attack == "attacked" else 0.0,
                        attack_lambda=20.0, local_steps=2, batch=4,
                        seq_len=32, lr=0.05, participation=0.5,
                        eval_every=max(rounds // 4, 1), seed=seed, quiet=True)
                    res[tag] = dict(global_acc=h["final_acc"],
                                    local_acc=h["final_local_acc"],
                                    secs=round(time.time() - t0, 1))
                    import jax
                    jax.clear_caches()   # 24 configs x several jits: keep
                    # the single-core container's RSS bounded
                    print(f"{tag:38s} g={h['final_acc']:.3f} "
                          f"l={h['final_local_acc']:.3f}", flush=True)
    # accuracy drops under attack (the paper's robustness metric)
    for mode, baseline in MODES:
        for dist in ["iid", "noniid"]:
            for strat in ["fedfa", baseline]:
                c = res[f"{mode}/{dist}/clean/{strat}"]["global_acc"]
                a = res[f"{mode}/{dist}/attacked/{strat}"]["global_acc"]
                res[f"{mode}/{dist}/drop/{strat}"] = round(c - a, 4)
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w") as f:
        json.dump(res, f, indent=1)
    return res


if __name__ == "__main__":
    import sys
    run(quick="--full" not in sys.argv)
