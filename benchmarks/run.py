"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (us_per_call = wall time of the
benchmark payload; derived = the table's headline metric).
"""
from __future__ import annotations

import json
import os
import sys
import time


def _row(name, us, derived):
    print(f"{name},{us:.0f},{derived}", flush=True)


def bench_table1(quick=True):
    from benchmarks import table1_robustness
    t0 = time.time()
    res = table1_robustness.run(quick=quick)
    drops_fedfa = [v for k, v in res.items() if "/drop/fedfa" in k]
    drops_base = [v for k, v in res.items()
                  if "/drop/" in k and not k.endswith("fedfa")]
    d = (f"fedfa_mean_drop={sum(drops_fedfa)/len(drops_fedfa):.3f};"
         f"baseline_mean_drop={sum(drops_base)/len(drops_base):.3f}")
    _row("table1_robustness", (time.time() - t0) * 1e6, d)


def bench_table2():
    from benchmarks import table2_macs
    t0 = time.time()
    res = table2_macs.run()
    _row("table2_macs", (time.time() - t0) * 1e6,
         f"avg_TMACs_both={res['both']['avg_TMACs']:.4f}")


def bench_table3(quick=True):
    from benchmarks import table3_perplexity
    t0 = time.time()
    res = table3_perplexity.run(quick=quick)
    fed = sum(v for k, v in res.items() if "/fedfa" in k) / 3
    base = sum(v for k, v in res.items() if "/fedfa" not in k) / 3
    _row("table3_perplexity", (time.time() - t0) * 1e6,
         f"fedfa_ppl={fed:.1f};baseline_ppl={base:.1f}")


def bench_table10(quick=True):
    from benchmarks import table10_scale_variation
    t0 = time.time()
    res = table10_scale_variation.run(quick=quick)
    ratios = [v["dist_over_baseline_mag"] for k, v in res.items()
              if "dist_over_baseline_mag" in v]
    _row("table10_scale_variation", (time.time() - t0) * 1e6,
         f"dist_ratio_range={min(ratios):.2f}-{max(ratios):.2f}")


def bench_appendixB(quick=True):
    from benchmarks import appendixB_similarity
    t0 = time.time()
    res = appendixB_similarity.run(quick=quick)
    _row("appendixB_similarity", (time.time() - t0) * 1e6,
         f"cos_init={res['epoch0']['functional_cos']:.3f};"
         f"cos_trained={res['trained']['functional_cos']:.3f}")


def bench_kernels():
    """Micro-bench the attention oracle (CPU wall time — indicative only;
    the Pallas kernels target TPU and are validated in interpret mode)."""
    import jax
    from repro.kernels.flash_attention import ref as fa_ref
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (2, 512, 4, 64))
    k = jax.random.normal(ks[1], (2, 512, 2, 64))
    v = jax.random.normal(ks[2], (2, 512, 2, 64))
    f = jax.jit(lambda q, k, v: fa_ref.attention_ref(q, k, v))
    f(q, k, v).block_until_ready()
    t0 = time.time()
    for _ in range(5):
        f(q, k, v).block_until_ready()
    _row("kernel_attention_ref_cpu", (time.time() - t0) / 5 * 1e6, "oracle")


def bench_aggregation():
    """Server aggregation throughput (params/s) at CPU scale."""
    import jax
    import jax.numpy as jnp
    from repro.configs import get_arch
    from repro.core import fedfa
    from repro.models import model as model_mod
    from repro.models.masks import full_client, stack_masks
    cfg = get_arch("smollm-135m").reduced()
    p = model_mod.init_params(cfg, jax.random.PRNGKey(0))
    m = 8
    stacked = jax.tree.map(lambda x: jnp.stack([x] * m), p)
    fc = full_client(cfg)
    masks = stack_masks([fc.masks(cfg)] * m)
    gates = jnp.stack([fc.gates(cfg)] * m)
    gmaps = jnp.stack([fc.graft(cfg)] * m)
    nd = jnp.ones((m,))
    # flat engine = the production server path (see benchmarks/bench_aggregate
    # for the tree-vs-flat comparison)
    f = jax.jit(lambda g, s: fedfa.aggregate(g, s, cfg, masks, gates, gmaps,
                                             nd, graft=True, scale=True,
                                             engine="flat"))
    jax.block_until_ready(f(p, stacked))
    n_params = sum(x.size for x in jax.tree.leaves(p))
    t0 = time.time()
    for _ in range(3):
        jax.block_until_ready(f(p, stacked))
    dt = (time.time() - t0) / 3
    _row("fedfa_aggregate_8clients", dt * 1e6,
         f"params_per_s={m*n_params/dt:.2e}")


def check() -> None:
    """Tier-1 CI gate: the repo's fast test suite plus smoke benchmarks of
    the resident round driver, the sharded round path, and the fused
    trimmed-quantile path (structural row-read/sort/collective gates), so
    perf and sharding regressions fail loudly alongside correctness ones.
    Exits non-zero on any failure.

        PYTHONPATH=src python benchmarks/run.py --check
    """
    import subprocess
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(root, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    # sharded smoke runs on a forced-4-device CPU backend so the cohort-axis
    # collectives are actually in the lowering (XLA_FLAGS is read at jax
    # init, hence a subprocess env, not a runtime switch)
    shard_env = dict(env)
    shard_env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                              " --xla_force_host_platform_device_count=4"
                              ).strip()
    steps = [
        ("tier-1 tests", [sys.executable, "-m", "pytest", "-x", "-q"], env),
        ("round-path smoke bench",
         [sys.executable, os.path.join(root, "benchmarks", "bench_round.py"),
          "--smoke", "--min-speedup", "1.5"], env),
        ("sharded-round smoke bench (4 forced CPU devices)",
         [sys.executable, os.path.join(root, "benchmarks", "bench_shard.py"),
          "--smoke"], shard_env),
        # 2x2 (data, model) smoke: reduce-scattered aggregation — gates
        # 0 all-gathers in the aggregation path, >= 1 reduce-scatter, and
        # per-device all-reduce volume N/n_model
        ("2-D sharded-round smoke bench (2x2 on 4 forced CPU devices)",
         [sys.executable, os.path.join(root, "benchmarks", "bench_shard.py"),
          "--smoke", "--model-shards", "2",
          "--out", "results/BENCH_shard_2d_smoke.json"], shard_env),
        ("quantile-path smoke bench (4 forced CPU devices)",
         [sys.executable,
          os.path.join(root, "benchmarks", "bench_quantile.py"),
          "--smoke"], shard_env),
        # async engine smoke: parity mode bit-equal to run_rounds, >= 1.3x
        # simulated rounds/sec over the sync driver under the skewed
        # device-class trace, zero all-gathers in the merge aggregation
        ("async-engine smoke bench (4 forced CPU devices)",
         [sys.executable, os.path.join(root, "benchmarks", "bench_async.py"),
          "--smoke", "--min-ratio", "1.3"], shard_env),
        # program-contract check: every declared Contract (round, agg,
        # async admit/merge, quantile) evaluated on freshly lowered
        # programs, plus the cache-key / recompile-audit passes.  --json
        # emits the machine-readable report validated below — trusting
        # exit status alone would miss a check that silently skipped a
        # program or dropped the peak-bytes fields.
        ("program-contract check (4 forced CPU devices)",
         [sys.executable, "-m", "repro.analysis", "check", "--quiet",
          "--json", os.path.join(root, "results", "ANALYSIS.json")],
         shard_env),
        ("FL source lints",
         [sys.executable, "-m", "repro.analysis", "lint",
          os.path.join(root, "src")], env),
    ]
    for name, cmd, step_env in steps:
        print(f"== {name}: {' '.join(cmd)}", flush=True)
        rc = subprocess.call(cmd, cwd=root, env=step_env)
        if rc != 0:
            print(f"CHECK FAILED at {name} (exit {rc})", flush=True)
            sys.exit(rc)
    problems = _validate_analysis_json(
        os.path.join(root, "results", "ANALYSIS.json"))
    if problems:
        for p in problems:
            print(f"ANALYSIS.json invalid: {p}", flush=True)
        print("CHECK FAILED at ANALYSIS.json validation", flush=True)
        sys.exit(1)
    print("CHECK OK", flush=True)


def _validate_analysis_json(path: str) -> list:
    """Sanity-gate the machine-readable contract report: all fifteen
    canonical programs are present (including the quantized round and
    quantized admit), every one declares AND measures
    peak_live_bytes_per_device, nothing failed, and the sharded programs
    carry collective provenance (blame) rows."""
    problems = []
    try:
        with open(path) as fh:
            data = json.load(fh)
    except (OSError, ValueError) as e:
        return [f"unreadable: {e}"]
    if not data.get("ok"):
        problems.append("top-level ok flag is false")
    progs = {p.get("program"): p for p in data.get("programs", [])}
    expected = ("round/ms1", "round/ms2", "round/quant",
                "agg/ms1", "agg/ms2",
                "async/admit", "async/admit-quant", "async/merge",
                "async/merge-ms2",
                "quantile/fused", "quantile/topk", "quantile/fused-pad",
                "quantile/topk-pad", "quantile/multilevel", "quantile/dist")
    for name in expected:
        p = progs.get(name)
        if p is None:
            problems.append(f"program {name} missing")
            continue
        if not p.get("ok") or p.get("violations"):
            problems.append(f"program {name} has violations: "
                            f"{p.get('violations')}")
        if "peak_live_bytes_per_device" not in p.get("spec", ""):
            problems.append(f"program {name} does not declare "
                            "peak_live_bytes_per_device")
        peak = p.get("measured", {}).get("peak_live_bytes_per_device")
        if not isinstance(peak, int) or peak <= 0:
            problems.append(f"program {name} measured no positive peak "
                            f"(got {peak!r})")
    if progs.get("round/ms2") and not progs["round/ms2"].get("blame"):
        problems.append("round/ms2 carries no collective blame rows "
                        "(metadata provenance lost?)")
    for pa in data.get("passes", []):
        if not pa.get("ok"):
            problems.append(f"pass {pa.get('name')} failed")
    return problems


def main() -> None:
    if "--check" in sys.argv:
        check()
        return
    quick = "--full" not in sys.argv
    os.makedirs("results", exist_ok=True)
    print("name,us_per_call,derived")
    bench_table2()
    bench_table10(quick)
    bench_appendixB(quick)
    bench_kernels()
    bench_aggregation()
    bench_table3(quick)
    bench_table1(quick)


if __name__ == "__main__":
    main()
