"""Table 3 analog: average local perplexity of a Transformer LM under the
six strategies (FedFA depth/width/both vs FlexiFed/HeteroFL/NeFL) on
synthetic domain-structured text standing in for WikiText-2."""
from __future__ import annotations

import json
import os

import numpy as np


def run(quick: bool = True, out: str = "results/table3.json",
        seed: int = 0) -> dict:
    import jax
    import jax.numpy as jnp
    from repro.configs import get_arch
    from repro.core.masking import apply_mask_tree, axis_mask_tree
    from repro.core.server import ClientSpec, FLConfig, fl_round
    from repro.data import synthetic
    from repro.launch.train import client_arch_pool
    from repro.models import model as model_mod

    cfg = get_arch("fedfa-paper-transformer").replace(
        vocab_size=256, n_layers=4, n_sections=2, d_model=128, d_ff=512,
        n_heads=2, n_kv_heads=2, max_seq_len=128)
    rounds = 8 if quick else 30
    n_clients, E, B, S = 8, 2, 4, 32
    key = jax.random.PRNGKey(seed)
    rng = np.random.default_rng(seed)
    domain_T = synthetic.make_bigram_lm(cfg.vocab_size, 4, seed=seed)
    client_domains = rng.integers(0, 4, n_clients)

    def perplexity(p, specs):
        """Average local perplexity of extracted client models."""
        pps = []
        for ci, s in enumerate(specs[:4]):
            toks = synthetic.lm_stream(
                cfg.vocab_size, 8, S, domain_T=[domain_T[client_domains[ci]]],
                seed=seed + 500 + ci)
            masks, gates = s.arch.masks(cfg), s.arch.gates(cfg)
            pm = apply_mask_tree(p, axis_mask_tree(cfg, masks))
            loss = model_mod.lm_loss(*[
                model_mod.forward(pm, cfg, {"tokens": jnp.asarray(toks)},
                                  masks=masks, gates=gates, remat=False)[0],
                jnp.asarray(toks)])
            pps.append(float(jnp.exp(loss)))
        return float(np.mean(pps))

    res = {}
    for mode, baseline in [("depth", "flexifed"), ("width", "heterofl"),
                           ("both", "nefl")]:
        pool = client_arch_pool(cfg, mode)
        specs = [ClientSpec(arch=pool[i % len(pool)], n_data=100)
                 for i in range(n_clients)]
        for strat in [f"fedfa", baseline]:
            params = model_mod.init_params(cfg, key)
            fl = FLConfig(local_steps=E, lr=0.1, strategy=strat, task="lm")
            for r in range(rounds):
                sel = rng.choice(n_clients, size=n_clients // 2, replace=False)
                toks = np.stack([
                    synthetic.lm_stream(
                        cfg.vocab_size, E * B, S,
                        domain_T=[domain_T[client_domains[ci]]],
                        seed=seed * 997 + r * 31 + ci).reshape(E, B, S)
                    for ci in sel])
                params, _ = fl_round(params, cfg, fl,
                                     [specs[i] for i in sel],
                                     {"tokens": jnp.asarray(toks)},
                                     jax.random.fold_in(key, r))
            pp = perplexity(params, specs)
            res[f"{mode}/{strat}"] = pp
            print(f"{mode:6s} {strat:9s} ppl={pp:8.2f}", flush=True)
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w") as f:
        json.dump(res, f, indent=1)
    return res


if __name__ == "__main__":
    import sys
    run(quick="--full" not in sys.argv)
