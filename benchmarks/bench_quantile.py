"""Trimmed-quantile path benchmark: fused Pallas kernel vs top_k tail path.

CPU wall-clock is NOT the gated signal here: the fused kernel only compiles
on TPU, so off-TPU it runs in Pallas interpret mode, which is slow by
construction (the json records wall times for transparency only).  The
stable, gated signals are structural, measured on the traced program:

  * row reads — compute ops consuming row-block-sized data: the fused
    kernel is ONE read of each cohort row (the 31-step count-and-partition
    refinement happens in VMEM), the top_k path is 4+ (abs, sort, compare,
    square-reduce);
  * sorts — the fused path contains zero sort/top_k ops;
  * collectives — on a multi-device backend the kernelized ``_cohort_norms``
    still lowers with ZERO all-gathers under the data mesh (PR 3's
    invariant; XLA's top_k partitioning is what used to re-gather);
  * the two-stage path (ISSUE 9) — rows past the single-pass VMEM budget
    dispatch to the multilevel kernel (still 1 row read / 0 sorts, never
    the jnp oracle), and under a 2x2 (data, model) mesh the distributed
    norms pass lowers with 0 all-gathers / reduce-scatters / all-to-alls
    and every all-reduce bounded by the histogram-plane payload
    (2·rows·paths·segs·bins elements — never O(N)).

Emits ``BENCH_quantile.json`` — the quantile-path trajectory anchor.

  PYTHONPATH=src python benchmarks/bench_quantile.py [--smoke]
  # multi-device collective check needs forced devices, e.g.:
  # XLA_FLAGS=--xla_force_host_platform_device_count=4
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time


def _structural(m, R, L, trim=0.95):
    """Trace both paths of the flat engine's per-leaf trimmed-norm pass on
    one (m, R, L) row block and count row reads / sorts — via the shared
    jaxpr visitor in ``repro.analysis.jaxpr`` (its pallas_call-is-one-read
    convention is the fusion being measured)."""
    import jax
    import jax.numpy as jnp
    from repro.analysis import jaxpr as jaxpr_mod
    from repro.core import flat

    rows = jax.random.normal(jax.random.PRNGKey(0), (m, R, L), jnp.float32)
    q = jnp.full((m,), 1.0 - (1.0 - trim) * 0.5, jnp.float32)

    def topk(rows, q):
        ra = jnp.abs(rows)
        t = flat._row_quantile(ra, q, trim)
        return jnp.sqrt(flat._rows_trimmed_sq(ra, t))

    def fused(rows, q):
        _, sq = flat._rows_trimmed_stats(rows, q, trim, True, True)
        return jnp.sqrt(sq)

    out = {}
    for name, fn in (("topk", topk), ("fused", fused)):
        c = jaxpr_mod.trace_counts(fn, rows, q, row_elems=rows.size)
        out[name] = {"row_reads": c.reads, "sorts": c.sorts}
    return out


def _structural_multilevel(R=2, L=(1 << 18) + 512, trim=0.95):
    """Trace the long-row dispatch: rows past ``_SINGLE_PASS_ELEMS`` must
    take the two-stage multilevel kernel — one row-sized read site, zero
    sorts, NOT the jnp oracle (which would show a sort)."""
    import jax
    import jax.numpy as jnp
    from repro.analysis import jaxpr as jaxpr_mod
    from repro.kernels.fedfa_quantile import ops as q_ops

    rows = jax.random.normal(jax.random.PRNGKey(2), (R, L), jnp.float32)
    q = jnp.full((R,), 1.0 - (1.0 - trim) * 0.5, jnp.float32)
    fn = lambda r, qq: q_ops.row_trimmed_stats(r, qq, use_kernel=True,
                                               interpret=True)
    c = jaxpr_mod.trace_counts(fn, rows, q, row_elems=rows.size)
    return {"rows": R, "row_len": L, "row_reads": c.reads, "sorts": c.sorts}


def _cohort_setup(model, m, mesh=None):
    import functools
    import jax
    import jax.numpy as jnp
    from repro.configs import get_arch
    from repro.core import flat
    from repro.models import model as model_mod
    from repro.models.masks import ClientArch, full_client, stack_masks
    from repro.sharding import cohort as csh

    cfg = get_arch(model).reduced().replace(n_layers=4, n_sections=2)
    g = model_mod.init_params(cfg, jax.random.PRNGKey(0))
    index = flat.get_index(g, pad_to=csh.pad_unit(mesh))
    pool = [ClientArch(0.25, (1, 1)), ClientArch(0.5, (2, 1)),
            ClientArch(1.0, (1, 2)), full_client(cfg)]
    masks = stack_masks([pool[i % len(pool)].masks(cfg) for i in range(m)])
    dens, fracs = jax.vmap(
        functools.partial(flat._density_and_fraction, cfg, index))(masks)
    xm = jax.random.normal(jax.random.PRNGKey(1), (m, index.n_padded),
                           jnp.float32) * dens
    return index, xm, fracs


def _wall(index, xm, fracs, iters, use_kernel, interpret):
    import jax
    from repro.core import flat

    fn = jax.jit(lambda x, f: flat._cohort_norms(
        index, x, f, 0.95, use_kernel, interpret))
    jax.block_until_ready(fn(xm, fracs))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(xm, fracs))
    return (time.perf_counter() - t0) / iters


def _collectives(index, xm, fracs, mesh):
    """Lower + compile the kernelized pass under the mesh; count collectives."""
    import jax
    from repro.analysis import hlo as coll
    from repro.core import flat
    from repro.sharding import cohort as csh

    fn = jax.jit(lambda x, f: flat._cohort_norms(
        index, x, f, 0.95, True, True, mesh=mesh))
    x = jax.device_put(xm, csh.cohort_sharding(mesh))
    fr = jax.device_put(fracs, csh.cohort_sharding(mesh))
    txt = fn.lower(x, fr).compile().as_text()
    return {kind: coll.count(txt, kind)
            for kind in ("all-reduce", "all-gather", "reduce-scatter",
                         "all-to-all")}


def _dist_collectives(index, xm, fracs, mesh):
    """Lower the DISTRIBUTED two-stage norms pass on the 2-D
    P("data", "model") layout and profile its cross-shard traffic."""
    import jax
    from repro.analysis import hlo as coll
    from repro.core import flat
    from repro.sharding import cohort as csh

    fn = jax.jit(lambda x, f: flat._cohort_norms(
        index, x, f, 0.95, True, True, mesh=mesh))
    x = jax.device_put(xm, csh.cohort_buffer_sharding(mesh))
    fr = jax.device_put(fracs, csh.cohort_sharding(mesh))
    txt = fn.lower(x, fr).compile().as_text()
    counts = {kind: coll.count(txt, kind)
              for kind in ("all-reduce", "all-gather", "reduce-scatter",
                           "all-to-all")}
    sizes = coll.sizes(txt, "all-reduce", min_elems=1)
    return counts, max(sizes, default=0)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="smollm-135m")
    ap.add_argument("--cohorts", nargs="+", type=int, default=[4, 16])
    ap.add_argument("--iters", type=int, default=5)
    ap.add_argument("--row-block", nargs=3, type=int, default=[4, 8, 512],
                    metavar=("M", "R", "L"),
                    help="(clients, rows, row length) for the structural "
                         "read/sort counts")
    ap.add_argument("--smoke", action="store_true",
                    help="m=4 only, 2 iters — the tier-1 CI configuration")
    ap.add_argument("--out", default=None,
                    help="output json (default: BENCH_quantile.json, or "
                         "results/BENCH_quantile_smoke.json with --smoke so "
                         "CI never clobbers the checked-in anchor)")
    args = ap.parse_args()
    if args.smoke:
        args.cohorts, args.iters = [4], 2
    if args.out is None:
        args.out = "results/BENCH_quantile_smoke.json" if args.smoke \
            else "BENCH_quantile.json"

    import jax
    from repro.launch.mesh import make_data_mesh

    m_s, r_s, l_s = args.row_block
    structural = _structural(m_s, r_s, l_s)
    results = {"backend": jax.default_backend(),
               "n_devices": jax.device_count(),
               "row_block": {"m": m_s, "rows": r_s, "row_len": l_s},
               "structural": structural, "runs": {}}
    ok = True
    print(f"row block ({m_s}, {r_s}, {l_s}):  "
          f"topk reads={structural['topk']['row_reads']} "
          f"sorts={structural['topk']['sorts']}  |  "
          f"fused reads={structural['fused']['row_reads']} "
          f"sorts={structural['fused']['sorts']}", flush=True)
    if structural["fused"]["row_reads"] != 1:
        print("FAIL: fused path does not read the row block exactly once",
              flush=True)
        ok = False
    if structural["fused"]["row_reads"] >= structural["topk"]["row_reads"]:
        print("FAIL: fused path does not beat the top_k path on row reads",
              flush=True)
        ok = False
    if structural["fused"]["sorts"] != 0 or structural["topk"]["sorts"] < 1:
        print("FAIL: sort counts wrong (fused must have none, top_k >= 1)",
              flush=True)
        ok = False

    # two-stage multilevel dispatch: long rows stay read-once / sort-free
    ml = _structural_multilevel()
    results["two_stage"] = {"multilevel": ml}
    print(f"multilevel ({ml['rows']}, {ml['row_len']}):  "
          f"reads={ml['row_reads']} sorts={ml['sorts']}", flush=True)
    if ml["row_reads"] != 1 or ml["sorts"] != 0:
        print("FAIL: long-row dispatch is not the read-once sort-free "
              "two-stage kernel (oracle fallback?)", flush=True)
        ok = False

    # distributed two-stage norms on a 2x2 (data, model) mesh: zero
    # gathers / re-layout collectives, all-reduces bounded by the
    # histogram planes — the model-replicated (m/D, N) transient is gone
    if jax.device_count() >= 4:
        from repro.kernels.fedfa_quantile.multilevel import histogram_elems
        from repro.launch.mesh import make_mesh_2d
        from repro.sharding import cohort as csh
        mesh2 = make_mesh_2d(2, 2)
        m2 = 4
        index2, xm2, fracs2 = _cohort_setup(args.model, m2, mesh=mesh2)
        counts2, max_ar = _dist_collectives(index2, xm2, fracs2, mesh2)
        hist = histogram_elems(m2 // csh.data_shards(mesh2),
                               index2.n_segments)
        rec2 = {"collectives": counts2,
                "max_all_reduce_elems": max_ar,
                "histogram_cap_elems": hist,
                "histogram_allreduce_bytes": max_ar * 4,
                "row_slice_elems_per_device":
                    (m2 // csh.data_shards(mesh2))
                    * (index2.n_padded // csh.model_shards(mesh2))}
        results["two_stage"]["distributed_2x2"] = rec2
        print(f"distributed 2x2 m={m2}:  collectives {counts2}  "
              f"max all-reduce {max_ar} elems (histogram cap {hist})",
              flush=True)
        if any(counts2.get(k, 0) for k in ("all-gather", "reduce-scatter",
                                           "all-to-all")):
            print("FAIL: re-layout collective(s) in the distributed "
                  f"two-stage norms pass: {counts2}", flush=True)
            ok = False
        if max_ar > hist:
            print(f"FAIL: all-reduce payload {max_ar} exceeds the "
                  f"histogram cap {hist} — O(N) traffic is back",
                  flush=True)
            ok = False

    for m in args.cohorts:
        index, xm, fracs = _cohort_setup(args.model, m)
        dt_topk = _wall(index, xm, fracs, args.iters, False, False)
        dt_fused = _wall(index, xm, fracs, args.iters, True, True)
        rec = {"n_params": index.n, "n_segments": index.n_segments,
               "topk_mean_s": round(dt_topk, 5),
               "fused_interpret_mean_s": round(dt_fused, 5)}
        if jax.device_count() > 1:
            counts = _collectives(index, xm, fracs, make_data_mesh())
            rec["collectives"] = counts
            if counts.get("all-gather", 0) > 0:
                print(f"FAIL: {counts['all-gather']} all-gather(s) in the "
                      f"kernelized _cohort_norms at m={m}", flush=True)
                ok = False
        results["runs"][f"{args.model}/m{m}"] = rec
        print(f"{args.model} m={m:3d}  topk {dt_topk*1e3:8.1f} ms  "
              f"fused(interpret) {dt_fused*1e3:8.1f} ms  "
              f"collectives {rec.get('collectives', 'n/a (1 device)')}",
              flush=True)

    out = args.out if os.path.isabs(args.out) else os.path.normpath(
        os.path.join(os.path.dirname(os.path.abspath(__file__)), "..",
                     args.out))
    os.makedirs(os.path.dirname(out) or ".", exist_ok=True)
    with open(out, "w") as f:
        json.dump(results, f, indent=1)
    print(f"wrote {out}")
    if not ok:
        sys.exit(1)


if __name__ == "__main__":
    main()
