"""Batched serving example: prefill + KV-cache decode with the Engine,
including a sliding-window (long-context variant) run.

Run:  PYTHONPATH=src python examples/serve_batched.py
"""
import time

import jax
import numpy as np

from repro.configs import get_arch
from repro.data import synthetic
from repro.launch.serve import Engine
from repro.models import model as model_mod

for arch, window in [("smollm-135m", None), ("mamba2-130m", None),
                     ("tinyllama-1.1b", 64)]:
    cfg = get_arch(arch).reduced()
    params = model_mod.init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, capacity=128,
                 window=window or cfg.attn_window)
    prompts = synthetic.lm_stream(cfg.vocab_size, 4, 24, seed=0)
    t0 = time.time()
    out = eng.generate(prompts, max_new=16, temperature=0.8)
    dt = time.time() - t0
    print(f"{arch:16s} window={window}  out={out.shape}  "
          f"{4*16/dt:6.1f} tok/s (CPU reduced config)")
