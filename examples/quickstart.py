"""Quickstart: one heterogeneous FedFA round end to end, on CPU.

Four clients pick different widths/depths, train locally on synthetic
non-IID data, the server grafts + scale-aggregates, and we inspect the
result.  ~30s on a laptop.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.core.server import ClientSpec, FLConfig, fl_round
from repro.data import synthetic
from repro.models import model as model_mod
from repro.models.masks import ClientArch

# 1) global architecture: a reduced SmolLM-family decoder (2 sections)
cfg = get_arch("smollm-135m").reduced().replace(
    n_layers=4, n_sections=2, vocab_size=64)
params = model_mod.init_params(cfg, jax.random.PRNGKey(0))
print(f"global model: {cfg.n_layers} layers, d_model={cfg.d_model}, "
      f"{sum(x.size for x in jax.tree.leaves(params))/1e6:.1f}M params")

# 2) clients choose architectures for their budget (Alg. 1 line 2)
specs = [
    ClientSpec(arch=ClientArch(0.25, (1, 1)), n_data=120),   # tiny phone
    ClientSpec(arch=ClientArch(0.5, (1, 2)), n_data=200),    # tablet
    ClientSpec(arch=ClientArch(0.75, (2, 1)), n_data=160),   # laptop
    ClientSpec(arch=ClientArch(1.0, (2, 2)), n_data=240),    # server
]

# 3) local data (synthetic LM streams; each client its own domain)
E, B, S = 2, 4, 32
toks = np.stack([
    synthetic.lm_stream(cfg.vocab_size, E * B, S, seed=i).reshape(E, B, S)
    for i in range(len(specs))])
batches = {"tokens": jnp.asarray(toks)}

# 4) one FedFA round: local updates -> graft -> scale -> aggregate
fl = FLConfig(local_steps=E, lr=0.05, strategy="fedfa", task="lm")
new_params, mean_loss = fl_round(params, cfg, fl, specs, batches,
                                 jax.random.PRNGKey(1))
print(f"round done; mean local loss {float(mean_loss):.3f}")

# 5) the global model changed everywhere (complete aggregation) ...
delta = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()),
                     new_params, params)
wq = new_params["stages"][0][0]["attn"]["wq"]
print("max |delta| embed:", delta["embed"])
print("depth slot 1 was missing from 3 of 4 clients, but grafting kept it "
      f"fully aggregated: |wq[1]-old| = "
      f"{float(jnp.abs(wq[1]-params['stages'][0][0]['attn']['wq'][1]).max()):.4f}")
