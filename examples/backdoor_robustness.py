"""Backdoor robustness demo (paper Fig. 3, miniature).

Runs the same federated classification workload under FedFA and under
NeFL-style partial aggregation, with 20% malicious clients at attack
intensity lambda=20, and prints the accuracy drop of each.

Run:  PYTHONPATH=src python examples/backdoor_robustness.py  (~5 min CPU)
"""
from repro.launch.train import run_fl

ROUNDS, CLIENTS = 12, 8

print("=== clean runs ===")
clean = {s: run_fl("smollm-135m", ROUNDS, CLIENTS, strategy=s,
                   arch_mode="both", local_steps=2, batch=4, seq_len=32,
                   lr=0.05, eval_every=6, seed=0, quiet=True)["final_acc"]
         for s in ["fedfa", "nefl"]}
print(clean)

print("=== attacked runs (20% malicious, lambda=20) ===")
attacked = {s: run_fl("smollm-135m", ROUNDS, CLIENTS, strategy=s,
                      arch_mode="both", malicious_frac=0.2,
                      attack_lambda=20.0, local_steps=2, batch=4,
                      seq_len=32, lr=0.05, eval_every=6, seed=0,
                      quiet=True)["final_acc"]
            for s in ["fedfa", "nefl"]}
print(attacked)

for s in ["fedfa", "nefl"]:
    print(f"{s:6s} clean={clean[s]:.3f} attacked={attacked[s]:.3f} "
          f"drop={clean[s]-attacked[s]:+.3f}")
print("expected (paper Table 1): FedFA's drop is smaller — layer grafting "
      "closes the incomplete-aggregation weak point.")
