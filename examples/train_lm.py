"""End-to-end driver (deliverable b): train a ~100M-class architecture
(SmolLM-135M family, reduced for CPU) for a few hundred steps of plain
distributed pretraining and watch the loss drop.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 200]
On a TPU pod the same step function is what launch/dryrun.py lowers for
the 16x16 mesh.
"""
import argparse

from repro.launch.train import run_dense

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=200)
ap.add_argument("--arch", default="smollm-135m")
ap.add_argument("--batch", type=int, default=8)
ap.add_argument("--seq-len", type=int, default=64)
args = ap.parse_args()

res = run_dense(args.arch, args.steps, args.batch, args.seq_len)
print(f"loss: first5={res['first']:.3f} -> last5={res['last']:.3f}")
assert res["last"] < res["first"], "loss should decrease"
print("OK: model is learning.")
