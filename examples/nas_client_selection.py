"""ZiCo NAS demo (paper §5.1): a client searches the (width x section-depth)
candidate grid with the zero-shot ZiCo proxy + evolutionary search and
reports the architecture it would register with the server.

Run:  PYTHONPATH=src python examples/nas_client_selection.py
"""
import jax

from repro.configs import get_arch
from repro.core.nas import SearchSpace, evolutionary_search, zico_score
from repro.models import model as model_mod
from repro.models.masks import ClientArch, max_section_depths

cfg = get_arch("smollm-135m").reduced().replace(
    n_layers=4, n_sections=2, vocab_size=64)
params = model_mod.init_params(cfg, jax.random.PRNGKey(0))

# a couple of probe minibatches of this client's local data
batches = {"tokens": jax.random.randint(jax.random.PRNGKey(1),
                                        (3, 2, 16), 0, cfg.vocab_size)}

full = ClientArch(1.0, max_section_depths(cfg))
print("ZiCo(full model)   =", f"{zico_score(cfg, full, params, batches):.3f}")
print("ZiCo(0.5x, half-depth) =",
      f"{zico_score(cfg, ClientArch(0.5, (1, 1)), params, batches):.3f}")

best = evolutionary_search(cfg, params, batches, population=6, generations=2,
                           space=SearchSpace(), seed=0)
print(f"selected architecture: width={best.width_mult} "
      f"depths={best.section_depths}")
print("the client reports this to the server (Alg. 1 line 2); the server "
      "extracts the matching sub-model every round (Alg. 3).")
