"""Integration: full FL rounds across strategies, attack robustness trend,
sharded lowering on a host mesh, and a short convergence run."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tiny

from repro.core.server import FLConfig, fl_round, make_client_specs
from repro.models import model as model_mod
from repro.models.masks import ClientArch


def _setup(vocab=64, n_clients=6, mal=0.0, seed=0):
    cfg = tiny("smollm-135m").replace(n_layers=4, n_sections=2,
                                      vocab_size=vocab)
    params = model_mod.init_params(cfg, jax.random.PRNGKey(seed))
    archs = [ClientArch(0.5, (1, 1)), ClientArch(0.75, (2, 1)),
             ClientArch(1.0, (2, 2))]
    specs = make_client_specs(cfg, n_clients, archs=archs,
                              malicious_frac=mal, seed=seed)
    E, B, S = 2, 2, 16
    batches = {"tokens": jax.random.randint(
        jax.random.PRNGKey(seed + 1), (n_clients, E, B, S), 0, vocab)}
    return cfg, params, specs, batches


@pytest.mark.parametrize("strategy", ["fedfa", "heterofl", "flexifed",
                                      "nefl", "fedfa-graft-only",
                                      "fedfa-scale-only"])
def test_round_all_strategies(strategy):
    cfg, params, specs, batches = _setup()
    fl = FLConfig(local_steps=2, lr=0.05, strategy=strategy)
    new_p, loss = fl_round(params, cfg, fl, specs, batches,
                           jax.random.PRNGKey(2))
    assert jnp.isfinite(loss)
    assert not any(bool(jnp.isnan(x).any()) for x in jax.tree.leaves(new_p))
    delta = max(float(jnp.abs(a - b).max())
                for a, b in zip(jax.tree.leaves(new_p), jax.tree.leaves(params)))
    assert delta > 0


@pytest.mark.slow
def test_attack_perturbs_fedfa_less_than_partial():
    """The paper's core claim, miniature: under a strong backdoor (lambda
    large, attacker on the largest arch), FedFA's global model moves less
    from the honest aggregate than incomplete aggregation does."""
    cfg, params, specs, batches = _setup(n_clients=6, mal=0.34, seed=3)
    lam = 20.0

    outs = {}
    for strategy in ["fedfa", "nefl"]:
        fl = FLConfig(local_steps=2, lr=0.05, strategy=strategy,
                      attack_lambda=lam)
        clean_specs = [type(s)(arch=s.arch, n_data=s.n_data, malicious=False,
                               class_mask=s.class_mask) for s in specs]
        p_att, _ = fl_round(params, cfg, fl, specs, batches,
                            jax.random.PRNGKey(4))
        p_cln, _ = fl_round(params, cfg, fl, clean_specs, batches,
                            jax.random.PRNGKey(4), any_malicious=False)
        dev = sum(float(jnp.sum(jnp.abs(a - b)))
                  for a, b in zip(jax.tree.leaves(p_att), jax.tree.leaves(p_cln)))
        norm = sum(float(jnp.sum(jnp.abs(b))) for b in jax.tree.leaves(p_cln))
        outs[strategy] = dev / norm
    assert outs["fedfa"] < outs["nefl"], outs


@pytest.mark.parametrize("engine", ["flat", "tree"])
def test_sharded_round_on_host_mesh(engine):
    """The SPMD FL round lowers and runs under a (1,1) mesh with the client
    axis marked for the data axis — the same program the pod runs — with
    either aggregation engine."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.mesh import make_host_mesh
    cfg, params, specs, batches = _setup()
    fl = FLConfig(local_steps=2, lr=0.05, strategy="fedfa", agg_engine=engine)
    mesh = make_host_mesh()
    with mesh:
        f = jax.jit(lambda p, b, k: fl_round(p, cfg, fl, specs, b, k),
                    in_shardings=(None,
                                  {"tokens": NamedSharding(mesh, P("data"))},
                                  None))
        new_p, loss = f(params, batches, jax.random.PRNGKey(0))
    assert jnp.isfinite(loss)


@pytest.mark.slow
def test_fl_converges_on_classification():
    from repro.launch.train import run_fl
    hist = run_fl("smollm-135m", rounds=6, n_clients=8, strategy="fedfa",
                  local_steps=2, batch=4, seq_len=32, lr=0.05,
                  participation=0.5, eval_every=5, seed=0)
    assert hist["global_acc"][-1] > hist["global_acc"][0] + 0.1
    assert hist["final_acc"] > 0.35
