"""Hypothesis property-based tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from conftest import tiny

from repro.core import fedfa
from repro.core.masking import apply_mask_tree, axis_mask_tree
from repro.models import model as model_mod
from repro.models.attention import _attend_dense, attend_blocked
from repro.models.masks import (ClientArch, depth_gates, graft_map,
                                max_section_depths, stack_masks, width_masks,
                                width_spec)

CFG = tiny("smollm-135m").replace(n_layers=4, n_sections=2, vocab_size=128)
PARAMS = model_mod.init_params(CFG, jax.random.PRNGKey(0))


@settings(max_examples=20, deadline=None)
@given(w=st.floats(0.2, 1.0))
def test_width_spec_monotone_and_valid(w):
    s = width_spec(CFG, w)
    assert 1 <= s.n_kv_heads <= CFG.n_kv_heads
    assert s.n_heads % s.n_kv_heads == 0
    assert s.n_heads // s.n_kv_heads == CFG.n_heads // CFG.n_kv_heads
    assert 0 < s.d_ff <= CFG.d_ff
    assert 0 < s.d_model <= CFG.d_model
    s2 = width_spec(CFG, min(1.0, w + 0.25))
    assert s2.d_ff >= s.d_ff and s2.n_heads >= s.n_heads


@settings(max_examples=15, deadline=None)
@given(d=st.tuples(st.integers(1, 2), st.integers(1, 2)))
def test_graft_map_idempotent_and_bounded(d):
    gm = np.asarray(graft_map(CFG, d))
    assert (gm[gm] == gm).all()                # idempotent (maps to active)
    g = np.asarray(depth_gates(CFG, d))
    assert (g[gm] == 1.0).all()                # targets are active blocks
    assert g.sum() == sum(d)


@settings(max_examples=10, deadline=None)
@given(w=st.sampled_from([0.25, 0.5, 0.75, 1.0]))
def test_extraction_idempotent(w):
    masks = width_masks(CFG, w)
    ax = axis_mask_tree(CFG, masks)
    p1 = apply_mask_tree(PARAMS, ax)
    p2 = apply_mask_tree(p1, ax)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        assert float(jnp.abs(a - b).max()) == 0.0


@settings(max_examples=8, deadline=None)
@given(scale=st.floats(0.5, 4.0), nd=st.integers(1, 20))
def test_aggregation_scale_equivariance(scale, nd):
    """aggregate(c*P) == c*aggregate(P) for homogeneous clients without
    scaling; with scaling, output is invariant to a COMMON rescale of all
    clients... no: alpha normalizes to the mean norm, so common rescale
    scales output by the same factor. Both checked."""
    m = 2
    ps = [model_mod.init_params(CFG, jax.random.PRNGKey(i + 5)) for i in range(m)]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *ps)
    scaled = jax.tree.map(lambda x: scale * x, stacked)
    from repro.models.masks import full_client
    fc = full_client(CFG)
    masks = stack_masks([fc.masks(CFG)] * m)
    gates = jnp.stack([fc.gates(CFG)] * m)
    gmaps = jnp.stack([fc.graft(CFG)] * m)
    ndv = jnp.full((m,), float(nd))
    a1 = fedfa.aggregate(PARAMS, stacked, CFG, masks, gates, gmaps, ndv,
                         graft=True, scale=True)
    a2 = fedfa.aggregate(jax.tree.map(lambda x: scale * x, PARAMS), scaled,
                         CFG, masks, gates, gmaps, ndv, graft=True, scale=True)
    for x, y in zip(jax.tree.leaves(a1), jax.tree.leaves(a2)):
        np.testing.assert_allclose(np.asarray(scale * x), np.asarray(y),
                                   rtol=2e-4, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(nd=st.lists(st.integers(1, 100), min_size=2, max_size=4))
def test_aggregation_respects_data_weights(nd):
    """gamma-weighted mean with N_c weights == np.average(weights=nd)."""
    m = len(nd)
    ps = [model_mod.init_params(CFG, jax.random.PRNGKey(i + 9)) for i in range(m)]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *ps)
    from repro.models.masks import full_client
    fc = full_client(CFG)
    masks = stack_masks([fc.masks(CFG)] * m)
    gates = jnp.stack([fc.gates(CFG)] * m)
    gmaps = jnp.stack([fc.graft(CFG)] * m)
    ndv = jnp.asarray(nd, jnp.float32)
    out = fedfa.aggregate(PARAMS, stacked, CFG, masks, gates, gmaps, ndv,
                          graft=False, scale=False)
    w = np.asarray(nd, np.float64) / sum(nd)
    for leaf, *client_leaves in zip(jax.tree.leaves(out),
                                    *[jax.tree.leaves(p) for p in ps]):
        exp = sum(wi * np.asarray(ci, np.float64)
                  for wi, ci in zip(w, client_leaves))
        np.testing.assert_allclose(np.asarray(leaf), exp, rtol=1e-4, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(sq=st.integers(17, 257), h=st.sampled_from([2, 4]),
       causal=st.booleans())
def test_blocked_attention_matches_dense(sq, h, causal):
    ks = jax.random.split(jax.random.PRNGKey(sq), 3)
    q = jax.random.normal(ks[0], (1, sq, h, 32))
    k = jax.random.normal(ks[1], (1, sq, h // 2 or 1, 32))
    v = jax.random.normal(ks[2], (1, sq, h // 2 or 1, 32))
    o1 = attend_blocked(q, k, v, causal=causal, bq=64, bk=64)
    o2 = _attend_dense(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-5)


@settings(max_examples=6, deadline=None)
@given(lam=st.floats(0.0, 4.0))
def test_malicious_combination_linear(lam):
    from repro.core.attacks import combine_malicious
    g = PARAMS
    h = jax.tree.map(lambda x: x + 1.0, g)
    b = jax.tree.map(lambda x: x - 2.0, g)
    out = combine_malicious(g, h, b, lam)
    exp = jax.tree.map(lambda x: x + 1.0 + lam * (-2.0), g)
    for a, e in zip(jax.tree.leaves(out), jax.tree.leaves(exp)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(e), atol=1e-4)
