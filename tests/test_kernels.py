"""Per-kernel shape/dtype sweeps, interpret-mode vs pure-jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.fedfa_agg import ops as agg_ops
from repro.kernels.fedfa_agg import ref as agg_ref
from repro.kernels.flash_attention import ref as fa_ref
from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ops import attention as fa_attention
from repro.kernels.ssd import ops as ssd_ops
from repro.kernels.ssd import ref as ssd_ref
from repro.kernels.ssd.kernel import ssd_intra_chunk
from repro.models.ssm import ssd_chunked_ref


def _tol(dtype):
    return dict(atol=5e-2, rtol=5e-2) if dtype == jnp.bfloat16 \
        else dict(atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("B,Sq,Sk,H,K,hd", [
    (2, 256, 256, 4, 2, 64),
    (1, 128, 128, 8, 8, 128),
    (2, 192, 192, 4, 1, 64),
    (1, 64, 320, 2, 2, 32),       # cross-length
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal,window", [(True, None), (True, 96), (False, None)])
def test_flash_attention_sweep(B, Sq, Sk, H, K, hd, dtype, causal, window):
    if Sq != Sk and causal:
        pytest.skip("causal cross-length not used by the stack")
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, Sq, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, Sk, K, hd), dtype)
    v = jax.random.normal(ks[2], (B, Sk, K, hd), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          bq=64, bk=64, interpret=True)
    exp = fa_ref.attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), **_tol(dtype))


def test_flash_attention_ops_padding():
    """ops wrapper pads ragged seq lens + head dims and unpads the result."""
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (2, 100, 4, 48))
    k = jax.random.normal(ks[1], (2, 100, 2, 48))
    v = jax.random.normal(ks[2], (2, 100, 2, 48))
    out = fa_attention(q, k, v, causal=True, use_kernel=True, interpret=True)
    exp = fa_ref.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=2e-5)


@pytest.mark.parametrize("b,S,nh,hp,N,Q", [
    (2, 96, 4, 32, 16, 32),
    (1, 128, 2, 64, 32, 64),
    (2, 70, 3, 32, 16, 32),      # ragged: S % Q != 0
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_kernel_sweep(b, S, nh, hp, N, Q, dtype):
    k = jax.random.PRNGKey(0)
    x = (jax.random.normal(k, (b, S, nh, hp)) * 0.5).astype(dtype)
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(k, 1), (b, S, nh)))
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(k, 2), (nh,)) * 0.2)
    B = (jax.random.normal(jax.random.fold_in(k, 3), (b, S, N)) * 0.3).astype(dtype)
    C = (jax.random.normal(jax.random.fold_in(k, 4), (b, S, N)) * 0.3).astype(dtype)
    y_k, h_k = ssd_ops.ssd(x, dt, A, B, C, Q, use_kernel=False, interpret=True)
    y_r, h_r = ssd_chunked_ref(x, dt, A, B, C, Q)
    np.testing.assert_allclose(np.asarray(y_k, np.float32),
                               np.asarray(y_r, np.float32), **_tol(dtype))
    np.testing.assert_allclose(np.asarray(h_k), np.asarray(h_r), **_tol(dtype))


def test_ssd_intra_chunk_vs_ref():
    G, Q, nh, hp, N = 4, 32, 2, 32, 16
    k = jax.random.PRNGKey(3)
    x = jax.random.normal(k, (G, Q, nh, hp))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(k, 1), (G, Q, nh)))
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(k, 2), (nh,)) * 0.1)
    B = jax.random.normal(jax.random.fold_in(k, 3), (G, Q, N)) * 0.3
    C = jax.random.normal(jax.random.fold_in(k, 4), (G, Q, N)) * 0.3
    yk, sk, Lk = ssd_intra_chunk(x, dt, A, B, C, interpret=True)
    yr, sr, Lr = ssd_ref.ssd_intra_chunk_ref(x, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(yk), np.asarray(yr), atol=1e-4)
    np.testing.assert_allclose(np.asarray(sk), np.asarray(sr), atol=1e-4)
    np.testing.assert_allclose(np.asarray(Lk), np.asarray(Lr), atol=1e-5)


@pytest.mark.parametrize("n", [1000, 4096, 50_000])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_trimmed_norm_sweep(n, dtype):
    w = jax.random.normal(jax.random.PRNGKey(0), (n,), dtype)
    t = jnp.quantile(jnp.abs(w.astype(jnp.float32)), 0.95)
    nk = agg_ops.trimmed_norm(w, t, interpret=True)
    nr = jnp.sqrt(agg_ref.trimmed_sumsq_ref(w, t))
    np.testing.assert_allclose(float(nk), float(nr), rtol=1e-3)


@pytest.mark.parametrize("m,n", [(3, 512), (8, 5000), (16, 12_345)])
def test_scaled_accum_sweep(m, n):
    k = jax.random.PRNGKey(0)
    x = jax.random.normal(k, (m, n))
    w = jax.random.uniform(jax.random.fold_in(k, 1), (m,))
    mask = (jnp.arange(n) < int(0.7 * n)).astype(jnp.float32)
    out = agg_ops.accumulate(x, w, mask, interpret=True)
    exp = agg_ref.scaled_accum_ref(x, w, mask)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=1e-4)


# Edge cases hit by the flat aggregation engine (interpret mode = the TPU
# kernel code path executed on CPU).

@pytest.mark.parametrize("n", [1, 127, 129, 2049, 4097])
def test_trimmed_norm_ragged_lengths(n):
    """Lengths not divisible by the 128-lane tile: zero-padding must not
    perturb the trimmed sum (|0| <= t contributes 0)."""
    w = jax.random.normal(jax.random.PRNGKey(n), (n,))
    t = jnp.quantile(jnp.abs(w), 0.95)
    nk = agg_ops.trimmed_norm(w, t, use_kernel=True, interpret=True)
    nr = jnp.sqrt(agg_ref.trimmed_sumsq_ref(w, t))
    np.testing.assert_allclose(float(nk), float(nr), rtol=1e-4, atol=1e-6)


@pytest.mark.parametrize("n", [130, 4097])
def test_scaled_accum_single_client(n):
    """m=1 degenerates to an elementwise scale; kernel must handle the
    single-row client axis."""
    x = jax.random.normal(jax.random.PRNGKey(0), (1, n))
    w = jnp.asarray([2.5])
    mask = (jnp.arange(n) % 3 != 0).astype(jnp.float32)
    out = agg_ops.accumulate(x, w, mask, use_kernel=True, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(2.5 * x[0] * mask),
                               atol=1e-5)


def test_trimmed_norm_all_masked_is_zero_not_nan():
    """An all-masked segment (every weight zeroed) has trimmed norm 0."""
    w = jnp.zeros((1000,))
    nk = agg_ops.trimmed_norm(w, jnp.asarray(0.0), use_kernel=True,
                              interpret=True)
    assert float(nk) == 0.0 and np.isfinite(float(nk))


def test_scaled_accum_all_masked_segment():
    """γ=0 segments: a zero mask yields exactly zero (the engine then keeps
    the previous global value instead of dividing 0/0 into NaN)."""
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 256))
    w = jnp.ones((4,))
    out = agg_ops.accumulate(x, w, jnp.zeros((256,)), use_kernel=True,
                             interpret=True)
    assert float(jnp.abs(out).max()) == 0.0
    assert not bool(jnp.isnan(out).any())
