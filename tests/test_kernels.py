"""Per-kernel shape/dtype sweeps, interpret-mode vs pure-jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.fedfa_agg import ops as agg_ops
from repro.kernels.fedfa_agg import ref as agg_ref
from repro.kernels.fedfa_quantile import ops as quant_ops
from repro.kernels.fedfa_quantile import ref as quant_ref
from repro.kernels.flash_attention import ref as fa_ref
from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ops import attention as fa_attention
from repro.kernels.ssd import ops as ssd_ops
from repro.kernels.ssd import ref as ssd_ref
from repro.kernels.ssd.kernel import ssd_intra_chunk
from repro.models.ssm import ssd_chunked_ref


def _tol(dtype):
    return dict(atol=5e-2, rtol=5e-2) if dtype == jnp.bfloat16 \
        else dict(atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("B,Sq,Sk,H,K,hd", [
    (2, 256, 256, 4, 2, 64),
    (1, 128, 128, 8, 8, 128),
    (2, 192, 192, 4, 1, 64),
    (1, 64, 320, 2, 2, 32),       # cross-length
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal,window", [(True, None), (True, 96), (False, None)])
def test_flash_attention_sweep(B, Sq, Sk, H, K, hd, dtype, causal, window):
    if Sq != Sk and causal:
        pytest.skip("causal cross-length not used by the stack")
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, Sq, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, Sk, K, hd), dtype)
    v = jax.random.normal(ks[2], (B, Sk, K, hd), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          bq=64, bk=64, interpret=True)
    exp = fa_ref.attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), **_tol(dtype))


def test_flash_attention_ops_padding():
    """ops wrapper pads ragged seq lens + head dims and unpads the result."""
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (2, 100, 4, 48))
    k = jax.random.normal(ks[1], (2, 100, 2, 48))
    v = jax.random.normal(ks[2], (2, 100, 2, 48))
    out = fa_attention(q, k, v, causal=True, use_kernel=True, interpret=True)
    exp = fa_ref.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=2e-5)


@pytest.mark.parametrize("b,S,nh,hp,N,Q", [
    (2, 96, 4, 32, 16, 32),
    (1, 128, 2, 64, 32, 64),
    (2, 70, 3, 32, 16, 32),      # ragged: S % Q != 0
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_kernel_sweep(b, S, nh, hp, N, Q, dtype):
    k = jax.random.PRNGKey(0)
    x = (jax.random.normal(k, (b, S, nh, hp)) * 0.5).astype(dtype)
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(k, 1), (b, S, nh)))
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(k, 2), (nh,)) * 0.2)
    B = (jax.random.normal(jax.random.fold_in(k, 3), (b, S, N)) * 0.3).astype(dtype)
    C = (jax.random.normal(jax.random.fold_in(k, 4), (b, S, N)) * 0.3).astype(dtype)
    y_k, h_k = ssd_ops.ssd(x, dt, A, B, C, Q, use_kernel=False, interpret=True)
    y_r, h_r = ssd_chunked_ref(x, dt, A, B, C, Q)
    np.testing.assert_allclose(np.asarray(y_k, np.float32),
                               np.asarray(y_r, np.float32), **_tol(dtype))
    np.testing.assert_allclose(np.asarray(h_k), np.asarray(h_r), **_tol(dtype))


def test_ssd_intra_chunk_vs_ref():
    G, Q, nh, hp, N = 4, 32, 2, 32, 16
    k = jax.random.PRNGKey(3)
    x = jax.random.normal(k, (G, Q, nh, hp))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(k, 1), (G, Q, nh)))
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(k, 2), (nh,)) * 0.1)
    B = jax.random.normal(jax.random.fold_in(k, 3), (G, Q, N)) * 0.3
    C = jax.random.normal(jax.random.fold_in(k, 4), (G, Q, N)) * 0.3
    yk, sk, Lk = ssd_intra_chunk(x, dt, A, B, C, interpret=True)
    yr, sr, Lr = ssd_ref.ssd_intra_chunk_ref(x, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(yk), np.asarray(yr), atol=1e-4)
    np.testing.assert_allclose(np.asarray(sk), np.asarray(sr), atol=1e-4)
    np.testing.assert_allclose(np.asarray(Lk), np.asarray(Lr), atol=1e-5)


@pytest.mark.parametrize("n", [1000, 4096, 50_000])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_trimmed_norm_sweep(n, dtype):
    w = jax.random.normal(jax.random.PRNGKey(0), (n,), dtype)
    t = jnp.quantile(jnp.abs(w.astype(jnp.float32)), 0.95)
    nk = agg_ops.trimmed_norm(w, t, interpret=True)
    nr = jnp.sqrt(agg_ref.trimmed_sumsq_ref(w, t))
    np.testing.assert_allclose(float(nk), float(nr), rtol=1e-3)


@pytest.mark.parametrize("m,n", [(3, 512), (8, 5000), (16, 12_345)])
def test_scaled_accum_sweep(m, n):
    k = jax.random.PRNGKey(0)
    x = jax.random.normal(k, (m, n))
    w = jax.random.uniform(jax.random.fold_in(k, 1), (m,))
    mask = (jnp.arange(n) < int(0.7 * n)).astype(jnp.float32)
    out = agg_ops.accumulate(x, w, mask, interpret=True)
    exp = agg_ref.scaled_accum_ref(x, w, mask)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=1e-4)


# Edge cases hit by the flat aggregation engine (interpret mode = the TPU
# kernel code path executed on CPU).

@pytest.mark.parametrize("n", [1, 127, 129, 2049, 4097])
def test_trimmed_norm_ragged_lengths(n):
    """Lengths not divisible by the 128-lane tile: zero-padding must not
    perturb the trimmed sum (|0| <= t contributes 0)."""
    w = jax.random.normal(jax.random.PRNGKey(n), (n,))
    t = jnp.quantile(jnp.abs(w), 0.95)
    nk = agg_ops.trimmed_norm(w, t, use_kernel=True, interpret=True)
    nr = jnp.sqrt(agg_ref.trimmed_sumsq_ref(w, t))
    np.testing.assert_allclose(float(nk), float(nr), rtol=1e-4, atol=1e-6)


@pytest.mark.parametrize("n", [130, 4097])
def test_scaled_accum_single_client(n):
    """m=1 degenerates to an elementwise scale; kernel must handle the
    single-row client axis."""
    x = jax.random.normal(jax.random.PRNGKey(0), (1, n))
    w = jnp.asarray([2.5])
    mask = (jnp.arange(n) % 3 != 0).astype(jnp.float32)
    out = agg_ops.accumulate(x, w, mask, use_kernel=True, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(2.5 * x[0] * mask),
                               atol=1e-5)


def test_trimmed_norm_all_masked_is_zero_not_nan():
    """An all-masked segment (every weight zeroed) has trimmed norm 0."""
    w = jnp.zeros((1000,))
    nk = agg_ops.trimmed_norm(w, jnp.asarray(0.0), use_kernel=True,
                              interpret=True)
    assert float(nk) == 0.0 and np.isfinite(float(nk))


def test_scaled_accum_all_masked_segment():
    """γ=0 segments: a zero mask yields exactly zero (the engine then keeps
    the previous global value instead of dividing 0/0 into NaN)."""
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 256))
    w = jnp.ones((4,))
    out = agg_ops.accumulate(x, w, jnp.zeros((256,)), use_kernel=True,
                             interpret=True)
    assert float(jnp.abs(out).max()) == 0.0
    assert not bool(jnp.isnan(out).any())


# Fused trimmed-quantile kernel (repro.kernels.fedfa_quantile): interpret
# mode = the TPU count-and-partition code path executed on CPU, against the
# pure-jnp jnp.quantile oracle.

def _quant_check(rows, q, rtol=1e-6, atol=1e-7):
    tk, sk = quant_ops.row_trimmed_stats(rows, q, use_kernel=True,
                                         interpret=True)
    tr, sr = quant_ref.row_trimmed_stats_ref(rows, q)
    np.testing.assert_allclose(np.asarray(tk), np.asarray(tr),
                               rtol=rtol, atol=atol)
    np.testing.assert_allclose(np.asarray(sk), np.asarray(sr),
                               rtol=rtol, atol=atol)
    return tk, sk


@pytest.mark.parametrize("R,L", [
    (1, 130),      # single row (m=1 cohort)
    (3, 1),        # L=1 segments (scalar leaves)
    (5, 127),      # ragged: below one 128-lane tile
    (7, 129),      # ragged: one tile + 1
    (8, 384),      # aligned rows and lanes (no-pad fast path)
    (2, 1000),     # ragged, multi-tile
])
def test_quantile_fused_ragged_sweep(R, L):
    """Ragged segment lengths: lane padding must not perturb threshold or
    trimmed sum (pad columns are masked out in-kernel)."""
    k = jax.random.PRNGKey(R * 1000 + L)
    rows = jax.random.normal(k, (R, L))
    q = jax.random.uniform(jax.random.fold_in(k, 1), (R,), minval=0.95,
                           maxval=1.0)
    _quant_check(rows, q)


def test_quantile_fused_q_endpoints():
    """q=1 (f→0, all-inactive leaf) selects the row max; q=trim (f=1) the
    plain trim quantile; q=0 the row min."""
    rows = jax.random.normal(jax.random.PRNGKey(0), (4, 257))
    for qv in (1.0, 0.95, 0.0):
        t, _ = _quant_check(rows, jnp.full((4,), qv))
    t1, s1 = quant_ops.row_trimmed_stats(rows, jnp.ones((4,)),
                                         use_kernel=True, interpret=True)
    np.testing.assert_array_equal(np.asarray(t1),
                                  np.asarray(jnp.abs(rows).max(axis=1)))
    np.testing.assert_allclose(np.asarray(s1),
                               np.asarray(jnp.sum(rows * rows, axis=1)),
                               rtol=1e-6)


def test_quantile_fused_all_masked_rows():
    """All-masked rows (every weight zeroed): t = 0 and Σ = 0, never NaN."""
    rows = jnp.zeros((5, 300))
    t, ss = quant_ops.row_trimmed_stats(rows, jnp.ones((5,)),
                                        use_kernel=True, interpret=True)
    assert float(jnp.abs(t).max()) == 0.0 and float(jnp.abs(ss).max()) == 0.0
    assert not bool(jnp.isnan(t).any() or jnp.isnan(ss).any())


def test_quantile_fused_threshold_on_tied_value():
    """A rank landing exactly on a run of ties must select the tied value
    itself and the trim test must keep every copy."""
    row = jnp.asarray([[1.0, 2.0, 2.0, 2.0, 3.0]])
    # p = 0.5 * 4 = 2 -> sorted[2] = 2.0 exactly, no interpolation
    t, ss = quant_ops.row_trimmed_stats(row, jnp.asarray([0.5]),
                                        use_kernel=True, interpret=True)
    assert float(t[0]) == 2.0
    assert float(ss[0]) == 1.0 + 3 * 4.0          # all three 2.0s kept
    _quant_check(row, jnp.asarray([0.5]))
    # interpolated position inside the tie run: t stays exactly 2.0
    t2, _ = quant_ops.row_trimmed_stats(row, jnp.asarray([0.375]),
                                        use_kernel=True, interpret=True)
    assert float(t2[0]) == 2.0


def test_quantile_fused_selection_is_bit_exact():
    """Integer sort positions (frac = 0): the count-and-partition search
    must return the sorted element bit-for-bit, not an approximation."""
    L = 129                                        # q = k/128 exact in f32
    rows = jax.random.normal(jax.random.PRNGKey(3), (4, L))
    srt = jnp.sort(jnp.abs(rows), axis=1)
    for k in (0, 1, 64, 127, 128):
        q = jnp.full((4,), k / 128.0, jnp.float32)
        t, _ = quant_ops.row_trimmed_stats(rows, q, use_kernel=True,
                                           interpret=True)
        np.testing.assert_array_equal(np.asarray(t), np.asarray(srt[:, k]))


def test_quantile_fused_bf16_cast_rows():
    """Rows that round-tripped through bf16 (heavy value ties at bf16
    resolution) still match the oracle exactly."""
    rows = jax.random.normal(jax.random.PRNGKey(4), (6, 500))
    rows = rows.astype(jnp.bfloat16).astype(jnp.float32)
    q = jax.random.uniform(jax.random.PRNGKey(5), (6,), minval=0.95,
                           maxval=1.0)
    _quant_check(rows, q)
