"""Sharded resident round (repro.sharding.cohort + mesh-aware round driver):
host-mesh parity, pad-row inertness, donation under NamedSharding, the
forced-multi-device subprocess parity, and regressions for the checkpoint /
rounds=0 / sanitize_specs / stack_runtimes fixes that rode along."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import assert_tree_allclose as _assert_tree_allclose
from conftest import fl_round_fixture, make_cohort

from repro.core import flat
from repro.core import round as round_mod
from repro.core import server as server_mod
from repro.core.server import FLConfig, stack_runtimes
from repro.launch.mesh import make_data_mesh
from repro.sharding import cohort as cohort_sh

CFG, PARAMS = fl_round_fixture()
E, M = 2, 3
KEY = jax.random.PRNGKey(0)


def _fl(strategy):
    return FLConfig(local_steps=E, lr=0.05, strategy=strategy, task="cls",
                    agg_engine="flat")


@pytest.fixture(scope="module")
def cohort():
    return make_cohort(CFG, M, local_steps=E)


# ---------------------------------------------------------------------------
# Sharded round: host mesh (however many devices this process sees)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("strategy", ["fedfa", "heterofl"])
def test_sharded_matches_unsharded_on_host_mesh(cohort, strategy):
    """run_rounds under the data mesh == run_rounds without a mesh."""
    specs, data_fn = cohort
    fl = _fl(strategy)
    p_un, l_un = round_mod.run_rounds(PARAMS, CFG, fl, 2, data_fn, KEY,
                                      eval_every=0)
    p_sh, l_sh = round_mod.run_rounds(PARAMS, CFG, fl, 2, data_fn, KEY,
                                      eval_every=0, mesh=make_data_mesh())
    np.testing.assert_allclose(l_un, l_sh, rtol=1e-4)
    _assert_tree_allclose(p_un, p_sh)


def test_donation_under_named_sharding(cohort):
    """The donated ping-pong of (N,)/(m, N) buffers survives explicit
    NamedShardings: inputs are consumed, outputs carry the cohort spec."""
    specs, data_fn = cohort
    fl = _fl("fedfa")
    mesh = make_data_mesh()
    index = flat.get_index(PARAMS)
    runtimes = stack_runtimes(CFG, specs)
    _, batches = data_fn(0)
    g_buf = jax.device_put(flat.flatten(index, PARAMS),
                           cohort_sh.replicated(mesh))
    g2, c2, _ = round_mod.flat_round(g_buf, None, CFG, fl, index, runtimes,
                                     batches, KEY, mesh=mesh)
    assert g_buf.is_deleted()
    g3, c3, _ = round_mod.flat_round(g2, c2, CFG, fl, index, runtimes,
                                     batches, KEY, mesh=mesh)
    assert g2.is_deleted() and c2.is_deleted()
    assert not (g3.is_deleted() or c3.is_deleted())
    assert c3.sharding.spec == jax.sharding.PartitionSpec("data")


def test_padded_cohort_aggregates_identically(cohort):
    """Pad rows are inert in Alg. 1: aggregate_buffers over the cohort
    padded with n_data = 0 rows equals the unpadded aggregation for both
    the scaled (fedfa: α mean must skip pads) and unscaled presets."""
    specs, data_fn = cohort
    index = flat.get_index(PARAMS)
    g_flat = flat.flatten(index, PARAMS)
    x = jnp.stack([g_flat * (1.0 + 0.01 * (i + 1)) for i in range(M)])
    runtimes = stack_runtimes(CFG, specs)
    (masks_p, gates_p, gmaps_p, nd_p, _, _), _ = cohort_sh.pad_cohort(
        runtimes, {"d": jnp.zeros((M, 1))}, pad=2)
    x_p = jnp.concatenate([x, jnp.broadcast_to(x[:1] * 7.0, (2,) + x.shape[1:])])
    masks, gates, gmaps, nd, _, _ = runtimes
    for graft, scale in [(True, True), (False, False), (True, False)]:
        out = flat.aggregate_buffers(index, g_flat, x, CFG, masks, gates,
                                     gmaps, nd, graft=graft, scale=scale)
        out_p = flat.aggregate_buffers(index, g_flat, x_p, CFG, masks_p,
                                       gates_p, gmaps_p, nd_p, graft=graft,
                                       scale=scale)
        np.testing.assert_allclose(np.asarray(out), np.asarray(out_p),
                                   rtol=1e-6, atol=1e-7)


def test_engines_agree_on_zero_data_client(cohort):
    """The flat engine's validity-weighted α mean and the tree engine's
    (scaling_factors with n_data) must stay parity-locked when a REAL
    client has n_data = 0, not just for sharding pad rows."""
    from repro.core import fedfa
    specs, _ = cohort
    masks, gates, gmaps, _, _, _ = stack_runtimes(CFG, specs)
    stacked = jax.tree.map(
        lambda l: jnp.stack([l * (1.0 + 0.02 * (i + 1)) for i in range(M)]),
        PARAMS)
    nd = jnp.asarray([120.0, 0.0, 90.0])
    out_flat = fedfa.aggregate(PARAMS, stacked, CFG, masks, gates, gmaps, nd,
                               graft=True, scale=True, engine="flat")
    out_tree = fedfa.aggregate(PARAMS, stacked, CFG, masks, gates, gmaps, nd,
                               graft=True, scale=True, engine="tree")
    _assert_tree_allclose(out_flat, out_tree)


def test_pad_cohort_rows():
    assert cohort_sh.pad_rows(3, None) == 0
    mesh = make_data_mesh()
    assert cohort_sh.pad_rows(3, mesh) == (-3) % mesh.shape["data"]
    nd = jnp.asarray([5.0, 7.0])
    mal = jnp.asarray([0.0, 1.0])
    gates = jnp.ones((2, 4))
    (_, gates_p, _, nd_p, cms_p, mal_p), batches_p = cohort_sh.pad_cohort(
        (gates, gates, gates, nd, None, mal), {"tokens": jnp.ones((2, 3))},
        pad=2)
    assert gates_p.shape == (4, 4) and batches_p["tokens"].shape == (4, 3)
    assert cms_p is None
    np.testing.assert_array_equal(np.asarray(nd_p), [5.0, 7.0, 0.0, 0.0])
    np.testing.assert_array_equal(np.asarray(mal_p), [0.0, 1.0, 0.0, 0.0])


def _run_forced_multidevice_child(*args):
    """Run tests/_force_multidevice_child.py on 4 forced CPU devices — in a
    subprocess because XLA_FLAGS is read once at jax init."""
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        " --xla_force_host_platform_device_count=4").strip()
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(root, "src"), os.path.join(root, "tests")] +
        ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    proc = subprocess.run(
        [sys.executable,
         os.path.join(root, "tests", "_force_multidevice_child.py"), *args],
        env=env, capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, f"child failed:\n{proc.stdout}\n{proc.stderr}"
    return proc.stdout


def test_sharded_round_forced_multidevice():
    """Sharded-vs-unsharded parity on 4 forced CPU devices — uneven m=3
    cohort (one pad shard), malicious client, fedfa + heterofl, donation."""
    assert "MULTIDEVICE OK" in _run_forced_multidevice_child()


def test_kernelized_quantile_collectives_forced_multidevice():
    """The kernelized trimmed-norm pass (fused Pallas fedfa_quantile,
    interpret mode) keeps the sharded aggregation's collective structure:
    zero all-gathers, <= 2 N-sized all-reduces under the host mesh."""
    out = _run_forced_multidevice_child("--quantile-collectives")
    assert "QUANTILE COLLECTIVES OK" in out


def test_two_d_round_forced_multidevice():
    """2x2 (data, model) resident round on 4 forced CPU devices: parity vs
    the 1-device round (fedfa + heterofl, uneven m=3, malicious client),
    N-pad-segment inertness through the full round, model-sharded resident
    buffers (N/2 bytes per device) with ping-pong donation, and a
    checkpoint roundtrip from/to the sharded global layout."""
    assert "TWO-D OK" in _run_forced_multidevice_child("--two-d")


def test_agg_collectives_2d_forced_multidevice():
    """The 2x2 aggregation path lowers with ZERO all-gathers, >= 1
    reduce-scatter, and no all-reduce above N/n_model elements."""
    out = _run_forced_multidevice_child("--agg-collectives-2d")
    assert "AGG COLLECTIVES 2D OK" in out


def test_async_forced_multidevice():
    """Async engine on 4 forced CPU devices: parity-mode bit-equality with
    the sharded run_rounds (fedfa + heterofl, uneven malicious cohort),
    skewed-trace bounded-staleness merges, zero all-gathers in the merge
    program, and the ResidentDriver._cbufs padded-key regression (m=3 and
    m=4 cohorts ping-pong one padded scratch allocation)."""
    assert "ASYNC OK" in _run_forced_multidevice_child("--async")


def test_quantized_forced_multidevice():
    """Quantized admission on 4 forced CPU devices: bf16/int8 sharded
    rounds stay within quantization drift of the sharded f32 round, and
    the ResidentDriver._cbufs dtype-key regression — one driver serving
    f32 and int8 cohorts of the same padded size keeps one pool per
    admission dtype and never donates across dtypes."""
    assert "QUANT OK" in _run_forced_multidevice_child("--quant")


# ---------------------------------------------------------------------------
# N-padding (host-side, no mesh needed)
# ---------------------------------------------------------------------------

def test_flat_index_n_padding_roundtrip_and_inertness():
    """A pad_to that does not divide N grows an inert zero tail: offsets are
    unchanged, flatten/unflatten round-trips, the tail has zero density and
    the padded aggregation equals the unpadded one with a zero tail."""
    tree = {"a": jnp.arange(3.0), "b": jnp.arange(4.0).reshape(2, 2)}
    idx1 = flat.get_index(tree)
    idx8 = flat.get_index(tree, pad_to=8)
    assert idx1.n == idx8.n == 7
    assert idx1.n_padded == 7 and idx8.n_padded == 8
    assert [s.offset for s in idx1.leaves] == [s.offset for s in idx8.leaves]
    assert idx8.row_of.shape == (8,) and idx8.g_base.shape == (8,)
    buf = flat.flatten(idx8, tree)
    assert buf.shape == (8,)
    np.testing.assert_array_equal(np.asarray(buf)[7:], 0.0)
    _assert_tree_allclose(flat.unflatten(idx8, buf), tree, rtol=0, atol=0)
    st = jax.tree.map(lambda l: jnp.stack([l, 2.0 * l]), tree)
    sbuf = flat.flatten_stacked(idx8, st)
    assert sbuf.shape == (2, 8)
    np.testing.assert_array_equal(np.asarray(sbuf)[:, 7:], 0.0)


def test_aggregate_buffers_pad_tail_is_inert():
    """On the real fixture, an aggregation through a padded index matches
    the unpadded aggregation on the logical prefix and keeps the tail 0."""
    index = flat.get_index(PARAMS)
    pad_to = 1024
    index_p = flat.get_index(PARAMS, pad_to=pad_to)
    assert index_p.n_padded > index_p.n, "fixture N divides pad_to"
    specs, _ = make_cohort(CFG, M, local_steps=E)
    masks, gates, gmaps, nd, _, _ = stack_runtimes(CFG, specs)
    g = flat.flatten(index, PARAMS)
    x = jnp.stack([g * (1.0 + 0.01 * (i + 1)) for i in range(M)])
    g_p = flat.flatten(index_p, PARAMS)
    x_p = jnp.pad(x, ((0, 0), (0, index_p.n_padded - index_p.n)))
    for graft, scale in [(True, True), (False, False)]:
        out = flat.aggregate_buffers(index, g, x, CFG, masks, gates, gmaps,
                                     nd, graft=graft, scale=scale)
        out_p = flat.aggregate_buffers(index_p, g_p, x_p, CFG, masks, gates,
                                       gmaps, nd, graft=graft, scale=scale)
        np.testing.assert_allclose(np.asarray(out_p)[:index.n],
                                   np.asarray(out), rtol=1e-6, atol=1e-7)
        np.testing.assert_array_equal(np.asarray(out_p)[index.n:], 0.0)


def test_round_cache_hits_on_reconstructed_mesh():
    """_ROUND_CACHE keys the mesh by value: an identical mesh rebuilt from
    the same devices/axes must reuse the compiled round program instead of
    recompiling every cohort shape."""
    index = flat.get_index(PARAMS)
    fl = _fl("fedfa")
    fn1 = round_mod.make_flat_round(CFG, fl, index, any_malicious=False,
                                    mesh=make_data_mesh())
    fn2 = round_mod.make_flat_round(CFG, fl, index, any_malicious=False,
                                    mesh=make_data_mesh())
    assert fn1 is fn2
    assert round_mod._mesh_key(make_data_mesh()) \
        == round_mod._mesh_key(make_data_mesh())
    assert round_mod._mesh_key(None) is None


def test_mesh_shape_validation_and_parsing():
    """get_mesh validates the requested shape against the visible device
    count, naming both, and accepts explicit DxM shapes."""
    from repro.launch import mesh as mesh_mod
    n_dev = jax.device_count()
    with pytest.raises(ValueError, match=rf"256 devices.*{n_dev} are visible"):
        mesh_mod.get_mesh("production")
    with pytest.raises(ValueError, match=rf"needs {8 * n_dev} devices"):
        mesh_mod.get_mesh(f"{8 * n_dev}x1")
    assert mesh_mod.parse_mesh_shape("2x2") == (2, 2)
    assert mesh_mod.parse_mesh_shape(" 4X2 ") == (4, 2)
    for bad in ("2x", "x2", "0x2", "2x2x2", "host"):
        with pytest.raises(ValueError):
            mesh_mod.parse_mesh_shape(bad)
    m = mesh_mod.get_mesh(f"{n_dev}x1")
    assert m.shape["data"] == n_dev and m.shape["model"] == 1
    with pytest.raises(ValueError, match="unknown mesh"):
        mesh_mod.get_mesh("banana")


# ---------------------------------------------------------------------------
# Satellite regressions
# ---------------------------------------------------------------------------

def test_restore_raises_on_structure_mismatch(tmp_path):
    from repro.checkpoint import checkpoint as ckpt_mod
    tree = {"a": np.zeros((2, 3), np.float32), "b": np.ones(4, np.float32)}
    path = str(tmp_path / "ck")
    ckpt_mod.save(path, tree)
    with pytest.raises(ValueError, match="structure mismatch"):
        ckpt_mod.restore(path, {"a": tree["a"], "c": tree["b"]})
    with pytest.raises(ValueError, match=r"shape mismatch at .*a"):
        ckpt_mod.restore(path, {"a": np.zeros((3, 2), np.float32),
                                "b": tree["b"]})


def test_run_rounds_zero_rounds_is_a_noop():
    fl = _fl("fedfa")

    def data_fn(r):                                    # must never be called
        raise AssertionError("rounds=0 must not touch data or compile")
    params, losses = round_mod.run_rounds(PARAMS, CFG, fl, 0, data_fn, KEY)
    assert params is PARAMS and losses == []


def test_run_fl_zero_rounds_returns_empty_history():
    from repro.launch.train import run_fl
    hist = run_fl("smollm-135m", rounds=0, n_clients=4, local_steps=1,
                  batch=2, seq_len=8, quiet=True)
    assert hist["round"] == [] and hist["final_acc"] is None
    assert hist["final_local_acc"] is None


def test_sanitize_specs_missing_axis_falls_back_to_replication():
    from jax.sharding import PartitionSpec as P
    from repro.sharding.specs import sanitize_specs
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    spec = {"fsdp": P(("pod", "data"), None), "tp": P(None, "model"),
            "pod_only": P("pod")}
    avals = {"fsdp": jax.ShapeDtypeStruct((4, 4), jnp.float32),
             "tp": jax.ShapeDtypeStruct((4, 4), jnp.float32),
             "pod_only": jax.ShapeDtypeStruct((4,), jnp.float32)}
    out = sanitize_specs(spec, avals, mesh)
    assert out["fsdp"] == P(None, None)          # "pod" absent -> replicate
    assert out["tp"] == P(None, "model")         # known axes untouched
    assert out["pod_only"] == P(None)


def test_stack_runtimes_memoizes_per_arch(cohort):
    specs, _ = cohort
    server_mod._RUNTIME_CACHE.clear()
    calls = {"n": 0}
    orig = type(specs[0].arch).masks

    def counting(self, cfg):
        calls["n"] += 1
        return orig(self, cfg)

    try:
        type(specs[0].arch).masks = counting
        stack_runtimes(CFG, specs)
        first = calls["n"]
        assert first == len({s.arch for s in specs})   # one build per arch
        stack_runtimes(CFG, specs)
        assert calls["n"] == first                     # second round: cached
    finally:
        type(specs[0].arch).masks = orig
    assert len(server_mod._RUNTIME_CACHE) <= server_mod._RUNTIME_CACHE_MAX
