"""Quantized cohort admission: the quantize/dequantize pair, pad-tail
inertness under a non-dividing ``pad_to``, the fused dequantize consumers
(accumulate + trimmed-quantile), and the quantized resident round state.

Drift-vs-oracle bounds over heterogeneous/malicious cohorts and the
error-feedback convergence sweep live in ``test_differential_oracle.py``;
this file pins the unit-level contracts.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import fl_round_fixture, make_cohort

from repro.core import flat
from repro.core import round as round_mod
from repro.core.server import FLConfig, stack_runtimes


@pytest.fixture(scope="module")
def fixture():
    cfg, params = fl_round_fixture()
    return cfg, params, flat.get_index(params)


def _fl(**kw):
    return FLConfig(local_steps=2, lr=0.05, strategy="fedfa", task="cls",
                    agg_engine="flat", **kw)


def test_update_dtype_of():
    assert flat.update_dtype_of("f32") == jnp.float32
    assert flat.update_dtype_of("bf16") == jnp.bfloat16
    assert flat.update_dtype_of("int8") == jnp.int8
    with pytest.raises(ValueError, match="update_dtype"):
        flat.update_dtype_of("fp4")


def test_quantize_roundtrip_bound(fixture):
    """int8 roundtrip error is bounded by half a quantization step per
    element — step = seg_max/127 per (client, segment) — and all-zero
    rows/segments carry scale 0 and roundtrip to exact zeros."""
    _, _, index = fixture
    m = 3
    key = jax.random.PRNGKey(7)
    x = jax.random.normal(key, (m, index.n_padded), jnp.float32)
    x = x.at[:, index.n:].set(0.0)       # the inert N-pad tail
    x = x.at[1].set(0.0)                 # an all-zero (pad-like) row
    x_q, scales = flat.quantize_cohort(index, x, "int8")
    assert x_q.dtype == jnp.int8 and scales.shape == (m, index.n_segments)
    np.testing.assert_array_equal(np.asarray(scales[1]), 0.0)
    back = flat.dequantize_cohort(index, x_q, scales)
    seg_id, _, _ = flat._segment_maps(index)
    col = np.where(np.asarray(seg_id) < 0, index.n_segments,
                   np.asarray(seg_id))
    step = np.concatenate([np.asarray(scales),
                           np.zeros((m, 1), np.float32)], axis=1)[:, col]
    err = np.abs(np.asarray(back) - np.asarray(x))
    assert (err <= 0.5 * step + 1e-7).all(), float(err.max())
    np.testing.assert_array_equal(np.asarray(back[1]), 0.0)

    # bf16 is a plain downcast with identity scales
    x_b, s_b = flat.quantize_cohort(index, x, "bf16")
    assert x_b.dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(s_b), 1.0)
    rel = np.abs(np.asarray(flat.dequantize_cohort(index, x_b, s_b)) -
                 np.asarray(x))
    assert (rel <= np.abs(np.asarray(x)) * 2 ** -7 + 1e-7).all()


def test_pad_tail_inert_under_quantization(fixture):
    """Satellite: with a ``pad_to`` that does NOT divide N the index gains
    a real inert tail; quantization must keep it inert — the tail's scale
    slot is the implicit 0, its stored int8 bits stay 0, and even garbage
    written into the tail dequantizes to exact zeros."""
    _, params, _ = fixture
    index = flat.get_index(params, pad_to=1024)
    assert index.n_padded > index.n, "pick a pad_to that does not divide N"
    m = 2
    x = jax.random.normal(jax.random.PRNGKey(3), (m, index.n_padded),
                          jnp.float32)                 # garbage in the tail
    x_q, scales = flat.quantize_cohort(index, x, "int8")
    np.testing.assert_array_equal(np.asarray(x_q[:, index.n:]), 0)
    back = flat.dequantize_cohort(index, x_q, scales)
    np.testing.assert_array_equal(np.asarray(back[:, index.n:]), 0.0)
    # a hand-poked nonzero tail still dequantizes to zero: its scale
    # column is the dropped S slot
    poked = x_q.at[:, index.n:].set(17)
    np.testing.assert_array_equal(
        np.asarray(flat.dequantize_cohort(index, poked, scales)[:, index.n:]),
        0.0)


def test_quantized_round_keeps_pad_tail_inert(fixture):
    """The full quantized resident round on a non-dividing ``pad_to``
    index: the merged global's tail stays exactly zero and the quantized
    pool never stores tail bits (scale-0 segments on pad rows)."""
    cfg, params, _ = fixture
    index = flat.get_index(params, pad_to=1024)
    assert index.n_padded > index.n
    specs, data_fn = make_cohort(cfg, 3, local_steps=2)
    runtimes = stack_runtimes(cfg, specs)
    _, batches = data_fn(0)
    fl = _fl(update_dtype="int8")
    g_buf = flat.flatten(index, params)
    g2, state, loss = round_mod.flat_round(
        g_buf, None, cfg, fl, index, runtimes, batches,
        jax.random.PRNGKey(0), any_malicious=False)
    assert np.isfinite(float(loss))
    x_q, scales, e_q, e_s = state
    np.testing.assert_array_equal(np.asarray(g2)[index.n:], 0.0)
    np.testing.assert_array_equal(np.asarray(x_q)[:, index.n:], 0)
    np.testing.assert_array_equal(np.asarray(e_q)[:, index.n:], 0)
    assert np.isfinite(np.asarray(scales)).all()
    assert np.isfinite(np.asarray(e_s)).all()


def test_fresh_quant_state_shapes(fixture):
    _, _, index = fixture
    st = round_mod.fresh_quant_state(index, 4, "int8")
    assert round_mod._quant_state_ok(st, 4, jnp.int8)
    assert not round_mod._quant_state_ok(st, 4, jnp.bfloat16)
    assert not round_mod._quant_state_ok(st, 5, jnp.int8)
    assert not round_mod._quant_state_ok(st[0], 4, jnp.int8)
    x_q, scales, e_q, e_s = st
    assert x_q.shape == (4, index.n_padded) and x_q.dtype == jnp.int8
    assert scales.shape == (4, index.n_segments)
    # zero EF pools are exact no-ops: scale 0 dequantizes to zeros
    np.testing.assert_array_equal(
        np.asarray(flat.dequantize_cohort(index, e_q, e_s)), 0.0)


def test_fused_accumulate_quant_matches_dequant_oracle():
    """``accumulate_quant`` (rows stay int8, scales fold into the
    per-(client, segment) weight table) equals the explicit
    dequantize-then-accumulate f32 oracle."""
    from repro.kernels.fedfa_agg import ops as agg_ops

    m, n, S = 5, 4096, 3
    key = jax.random.PRNGKey(11)
    seg = np.repeat(np.arange(S), n // S).astype(np.int32)
    seg = np.pad(seg, (0, n - seg.size), constant_values=-1)   # inert tail
    x = jax.random.normal(key, (m, n), jnp.float32)
    scales = 0.01 + jax.random.uniform(jax.random.fold_in(key, 1), (m, S))
    x_q = jnp.clip(jnp.round(
        x / jnp.take(scales, jnp.clip(jnp.asarray(seg), 0, S - 1), axis=1)),
        -127, 127).astype(jnp.int8)
    w = jax.random.uniform(jax.random.fold_in(key, 2), (m,)) + 0.1
    gtab = jax.random.uniform(jax.random.fold_in(key, 3), (m, S)) + 0.5
    mask = (jnp.asarray(seg) >= 0).astype(jnp.float32)

    wtab = gtab * scales                       # dequant folds into the table
    for kernel in (False, True):
        got = agg_ops.accumulate_quant(
            x_q, w, wtab, jnp.asarray(seg), mask,
            use_kernel=kernel, interpret=kernel)
        segc = jnp.clip(jnp.asarray(seg), 0, S - 1)
        dq = x_q.astype(jnp.float32) * jnp.take(scales, segc, axis=1)
        want = jnp.einsum("m,mn->n", w,
                          dq * jnp.take(gtab, segc, axis=1)) * mask
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)


def test_trimmed_stats_scale_matches_dequant_oracle():
    """Both quantile paths accept quantized rows + a per-row scale and
    match the reference run on the explicitly dequantized f32 rows."""
    from repro.kernels.fedfa_quantile import ops as q_ops
    from repro.kernels.fedfa_quantile.multilevel import \
        row_trimmed_stats_multilevel
    from repro.kernels.fedfa_quantile.ref import row_trimmed_stats_ref

    R, L = 4, 1536
    key = jax.random.PRNGKey(5)
    x = jax.random.normal(key, (R, L), jnp.float32)
    scale = jnp.max(jnp.abs(x), axis=1) / 127.0
    x_q = jnp.clip(jnp.round(x / scale[:, None]), -127, 127).astype(jnp.int8)
    q = jnp.asarray([0.8, 0.85, 0.9, 0.95], jnp.float32)
    dq = x_q.astype(jnp.float32) * scale[:, None]
    t_ref, ss_ref = row_trimmed_stats_ref(dq, q)

    t, ss = q_ops.row_trimmed_stats(x_q, q, scale=scale,
                                    use_kernel=True, interpret=True)
    np.testing.assert_allclose(np.asarray(t), np.asarray(t_ref),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(ss), np.asarray(ss_ref),
                               rtol=1e-4, atol=1e-5)

    t_m, ss_m = row_trimmed_stats_multilevel(x_q, q, scale=scale,
                                             interpret=True)
    np.testing.assert_allclose(np.asarray(t_m), np.asarray(t_ref),
                               rtol=5e-3, atol=1e-5)
    np.testing.assert_allclose(np.asarray(ss_m), np.asarray(ss_ref),
                               rtol=5e-3, atol=1e-5)


def test_per_round_driver_falls_back_to_f32(capsys):
    """``--update-dtype`` needs a resident cohort state; the per-round
    driver has none, so run_fl downgrades to f32 with a notice instead of
    crashing mid-run."""
    from repro.launch.train import run_fl

    hist = run_fl("smollm-135m", 1, 2, driver="per-round",
                  update_dtype="int8", local_steps=1, batch=2, seq_len=8,
                  participation=1.0, eval_every=0)
    assert np.isfinite(hist["loss"]).all()
    assert "f32" in capsys.readouterr().out
