"""Property tests for the flat engine's exact tail quantile
(``flat._row_quantile``) against ``jnp.quantile`` at the edges the
top-(1-trim) tail trick could miss: endpoint quantile levels (f→0 and f=1
active fractions), L=1 rows, trim values where the tail size k clamps to
the full row, and bf16-cast rows (heavy ties)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import flat


def _ref_quantile(rows_abs, q):
    """vmapped jnp.quantile: (m, R, L) rows + per-client q (m,) -> (m, R)."""
    return jax.vmap(lambda r, qq: jnp.quantile(r, qq, axis=-1))(rows_abs, q)


def _rows(m, R, L, seed=0):
    return jnp.abs(jax.random.normal(jax.random.PRNGKey(seed), (m, R, L)))


@pytest.mark.parametrize("seed", range(4))
@pytest.mark.parametrize("m,R,L", [(3, 2, 57), (2, 5, 260), (1, 1, 33)])
def test_row_quantile_matches_jnp_interior(seed, m, R, L):
    """Random shifted levels q in [trim, 1] — the production regime."""
    trim = 0.95
    rows = _rows(m, R, L, seed)
    q = jax.random.uniform(jax.random.PRNGKey(seed + 100), (m,),
                           minval=trim, maxval=1.0)
    np.testing.assert_allclose(
        np.asarray(flat._row_quantile(rows, q, trim)),
        np.asarray(_ref_quantile(rows, q)), rtol=1e-6, atol=1e-7)


@pytest.mark.parametrize("L", [1, 2, 50, 129])
def test_row_quantile_endpoint_q_one(L):
    """f→0 (all-inactive leaf) shifts the level to q=1: the row max, even
    though the interpolation indices sit at the very end of the tail."""
    rows = _rows(2, 3, L, seed=L)
    q = jnp.ones((2,))
    np.testing.assert_array_equal(
        np.asarray(flat._row_quantile(rows, q, 0.95)),
        np.asarray(rows.max(axis=-1)))


@pytest.mark.parametrize("trim", [0.95, 0.5])
def test_row_quantile_endpoint_q_trim(trim):
    """f=1 (fully active leaf) keeps q=trim — the lowest level the tail
    trick supports; the floor index is the deepest element the k-tail holds."""
    rows = _rows(3, 2, 101, seed=7)
    q = jnp.full((3,), trim)
    np.testing.assert_allclose(
        np.asarray(flat._row_quantile(rows, q, trim)),
        np.asarray(_ref_quantile(rows, q)), rtol=1e-6, atol=1e-7)


def test_row_quantile_single_element_rows():
    """L=1 (scalar leaves): every level returns the single element."""
    rows = _rows(4, 3, 1, seed=1)
    for qv in (0.95, 0.97, 1.0):
        np.testing.assert_array_equal(
            np.asarray(flat._row_quantile(rows, jnp.full((4,), qv), 0.95)),
            np.asarray(rows[..., 0]))


@pytest.mark.parametrize("L", [1, 2, 3])
def test_row_quantile_k_clamps_to_L(L):
    """Small rows where k = ceil((1-trim)(L-1))+2 >= L clamps to the full
    row: the 'tail' is the whole row and any q in [trim, 1] must be exact."""
    trim = 0.95
    assert min(L, int(np.ceil((1 - trim) * (L - 1))) + 2) == L
    rows = _rows(2, 4, L, seed=L + 10)
    q = jax.random.uniform(jax.random.PRNGKey(L), (2,), minval=trim,
                           maxval=1.0)
    np.testing.assert_allclose(
        np.asarray(flat._row_quantile(rows, q, trim)),
        np.asarray(_ref_quantile(rows, q)), rtol=1e-6, atol=1e-7)


def test_row_quantile_trim_zero_full_sort_regime():
    """trim=0 degenerates the tail to a full top_k: arbitrary q in [0, 1]
    must match jnp.quantile (k clamps to L for any L)."""
    rows = _rows(3, 2, 40, seed=2)
    q = jnp.asarray([0.0, 0.31, 1.0])
    np.testing.assert_allclose(
        np.asarray(flat._row_quantile(rows, q, 0.0)),
        np.asarray(_ref_quantile(rows, q)), rtol=1e-6, atol=1e-7)


def test_row_quantile_bf16_cast_rows():
    """bf16-cast rows tie heavily at bf16 resolution; the tail selection
    must still agree with the full-sort reference."""
    rows = _rows(3, 2, 300, seed=3).astype(jnp.bfloat16).astype(jnp.float32)
    q = jax.random.uniform(jax.random.PRNGKey(9), (3,), minval=0.95,
                           maxval=1.0)
    np.testing.assert_allclose(
        np.asarray(flat._row_quantile(rows, q, 0.95)),
        np.asarray(_ref_quantile(rows, q)), rtol=1e-6, atol=1e-7)


def test_row_quantile_all_zero_rows():
    """All-inactive (fully masked) leaves: zero rows give a zero threshold
    at every level, so the trimmed norm is 0 rather than NaN."""
    rows = jnp.zeros((2, 3, 64))
    out = flat._row_quantile(rows, jnp.asarray([0.95, 1.0]), 0.95)
    np.testing.assert_array_equal(np.asarray(out), 0.0)
