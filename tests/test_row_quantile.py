"""Property tests for the flat engine's exact tail quantile
(``flat._row_quantile``) against ``jnp.quantile`` at the edges the
top-(1-trim) tail trick could miss: endpoint quantile levels (f→0 and f=1
active fractions), L=1 rows, trim values where the tail size k clamps to
the full row, and bf16-cast rows (heavy ties)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import flat


def _ref_quantile(rows_abs, q):
    """vmapped jnp.quantile: (m, R, L) rows + per-client q (m,) -> (m, R)."""
    return jax.vmap(lambda r, qq: jnp.quantile(r, qq, axis=-1))(rows_abs, q)


def _rows(m, R, L, seed=0):
    return jnp.abs(jax.random.normal(jax.random.PRNGKey(seed), (m, R, L)))


@pytest.mark.parametrize("seed", range(4))
@pytest.mark.parametrize("m,R,L", [(3, 2, 57), (2, 5, 260), (1, 1, 33)])
def test_row_quantile_matches_jnp_interior(seed, m, R, L):
    """Random shifted levels q in [trim, 1] — the production regime."""
    trim = 0.95
    rows = _rows(m, R, L, seed)
    q = jax.random.uniform(jax.random.PRNGKey(seed + 100), (m,),
                           minval=trim, maxval=1.0)
    np.testing.assert_allclose(
        np.asarray(flat._row_quantile(rows, q, trim)),
        np.asarray(_ref_quantile(rows, q)), rtol=1e-6, atol=1e-7)


@pytest.mark.parametrize("L", [1, 2, 50, 129])
def test_row_quantile_endpoint_q_one(L):
    """f→0 (all-inactive leaf) shifts the level to q=1: the row max, even
    though the interpolation indices sit at the very end of the tail."""
    rows = _rows(2, 3, L, seed=L)
    q = jnp.ones((2,))
    np.testing.assert_array_equal(
        np.asarray(flat._row_quantile(rows, q, 0.95)),
        np.asarray(rows.max(axis=-1)))


@pytest.mark.parametrize("trim", [0.95, 0.5])
def test_row_quantile_endpoint_q_trim(trim):
    """f=1 (fully active leaf) keeps q=trim — the lowest level the tail
    trick supports; the floor index is the deepest element the k-tail holds."""
    rows = _rows(3, 2, 101, seed=7)
    q = jnp.full((3,), trim)
    np.testing.assert_allclose(
        np.asarray(flat._row_quantile(rows, q, trim)),
        np.asarray(_ref_quantile(rows, q)), rtol=1e-6, atol=1e-7)


def test_row_quantile_single_element_rows():
    """L=1 (scalar leaves): every level returns the single element."""
    rows = _rows(4, 3, 1, seed=1)
    for qv in (0.95, 0.97, 1.0):
        np.testing.assert_array_equal(
            np.asarray(flat._row_quantile(rows, jnp.full((4,), qv), 0.95)),
            np.asarray(rows[..., 0]))


@pytest.mark.parametrize("L", [1, 2, 3])
def test_row_quantile_k_clamps_to_L(L):
    """Small rows where k = ceil((1-trim)(L-1))+2 >= L clamps to the full
    row: the 'tail' is the whole row and any q in [trim, 1] must be exact."""
    trim = 0.95
    assert min(L, int(np.ceil((1 - trim) * (L - 1))) + 2) == L
    rows = _rows(2, 4, L, seed=L + 10)
    q = jax.random.uniform(jax.random.PRNGKey(L), (2,), minval=trim,
                           maxval=1.0)
    np.testing.assert_allclose(
        np.asarray(flat._row_quantile(rows, q, trim)),
        np.asarray(_ref_quantile(rows, q)), rtol=1e-6, atol=1e-7)


def test_row_quantile_trim_zero_full_sort_regime():
    """trim=0 degenerates the tail to a full top_k: arbitrary q in [0, 1]
    must match jnp.quantile (k clamps to L for any L)."""
    rows = _rows(3, 2, 40, seed=2)
    q = jnp.asarray([0.0, 0.31, 1.0])
    np.testing.assert_allclose(
        np.asarray(flat._row_quantile(rows, q, 0.0)),
        np.asarray(_ref_quantile(rows, q)), rtol=1e-6, atol=1e-7)


def test_row_quantile_bf16_cast_rows():
    """bf16-cast rows tie heavily at bf16 resolution; the tail selection
    must still agree with the full-sort reference."""
    rows = _rows(3, 2, 300, seed=3).astype(jnp.bfloat16).astype(jnp.float32)
    q = jax.random.uniform(jax.random.PRNGKey(9), (3,), minval=0.95,
                           maxval=1.0)
    np.testing.assert_allclose(
        np.asarray(flat._row_quantile(rows, q, 0.95)),
        np.asarray(_ref_quantile(rows, q)), rtol=1e-6, atol=1e-7)


def test_row_quantile_all_zero_rows():
    """All-inactive (fully masked) leaves: zero rows give a zero threshold
    at every level, so the trimmed norm is 0 rather than NaN."""
    rows = jnp.zeros((2, 3, 64))
    out = flat._row_quantile(rows, jnp.asarray([0.95, 1.0]), 0.95)
    np.testing.assert_array_equal(np.asarray(out), 0.0)


# ---------------------------------------------------------------------------
# two-stage multilevel kernel (ISSUE 9): exactness vs jnp.quantile
# ---------------------------------------------------------------------------

from repro.kernels.fedfa_quantile import multilevel as ml  # noqa: E402
from repro.kernels.fedfa_quantile import ops as qops  # noqa: E402


def _ulp_dist(a, b):
    """ulp distance between nonnegative f32 arrays (bit-pattern distance —
    monotone for same-sign floats)."""
    ai = np.asarray(a, np.float32).view(np.int32).astype(np.int64)
    bi = np.asarray(b, np.float32).view(np.int32).astype(np.int64)
    return np.abs(ai - bi)


def _check_multilevel(rows, q, rtol_ss=1e-5):
    """Pin the multilevel path against jnp.quantile on |rows|:

      * integral ranks (frac = 0) are pure order statistics — bit-equal;
      * interpolated thresholds are within 1 ulp of jnp's linear method
        (the reference's LAST ulp depends on whether XLA contracts the
        lerp into an fma, which is not part of the algorithm's contract);
      * t is bracketed by the 'lower'/'higher' order statistics, bitwise;
      * the fused trimmed Σw² matches a masked reference at the kernel's
        own threshold (rtol: summation order differs).
    """
    rows = jnp.asarray(rows, jnp.float32)
    q = jnp.asarray(q, jnp.float32)
    t, ss = ml.row_trimmed_stats_multilevel(rows, q, interpret=True)
    a_abs = jnp.abs(rows)
    ref = np.asarray(jax.vmap(jnp.quantile)(a_abs, q))
    lo = np.asarray(jax.vmap(
        lambda r, qq: jnp.quantile(r, qq, method="lower"))(a_abs, q))
    hi = np.asarray(jax.vmap(
        lambda r, qq: jnp.quantile(r, qq, method="higher"))(a_abs, q))
    t_np = np.asarray(t)
    # same f32 rank arithmetic as jnp.quantile: position, floor, fraction
    L = rows.shape[1]
    p = np.asarray(q, np.float32) * np.float32(L - 1)
    frac = p - np.floor(p)
    np.testing.assert_array_equal(t_np[frac == 0], ref[frac == 0])
    assert (_ulp_dist(t_np, ref) <= 1).all(), \
        f"threshold off by >1 ulp: {t_np} vs {ref}"
    assert (t_np >= lo).all() and (t_np <= hi).all()
    a_np = np.asarray(a_abs, np.float32)
    ref_ss = np.where(a_np <= t_np[:, None], a_np.astype(np.float64) ** 2,
                      0.0).sum(axis=1)
    np.testing.assert_allclose(np.asarray(ss), ref_ss, rtol=rtol_ss,
                               atol=1e-7)
    return t_np, frac


def test_multilevel_long_rows_vs_jnp():
    """L > 2**18 — past the single-pass VMEM budget, the regime the old
    dispatch silently handed to the jnp oracle.  q = 1 exercises an exact
    endpoint order statistic on the same long rows."""
    L = 2 ** 18 + 1536                       # tile-divisible: no pad column
    rows = jax.random.normal(jax.random.PRNGKey(0), (2, L), jnp.float32)
    _check_multilevel(rows, jnp.asarray([0.9731, 1.0]), rtol_ss=1e-4)


def test_multilevel_long_rows_integral_rank_bit_equal():
    """Integral-rank levels on L > 2**18 rows are pure order statistics and
    must be BIT-equal to jnp.quantile — the acceptance clause of ISSUE 9.
    Ranks are screened host-side with the same f32 arithmetic both sides
    use, so every case asserted is genuinely interpolation-free."""
    L = 2 ** 18 + 1536
    ks, qs = [], []
    for k in (0, 7919, L // 2, L - 2, L - 1):
        qv = np.float32(k) / np.float32(L - 1)
        if np.float32(qv) * np.float32(L - 1) == np.float32(k):
            ks.append(k)
            qs.append(qv)
    assert len(ks) >= 2, "no integral f32 ranks found"
    rows = jax.random.normal(jax.random.PRNGKey(1), (len(ks), L),
                             jnp.float32)
    t_np, frac = _check_multilevel(rows, jnp.asarray(qs), rtol_ss=1e-4)
    assert (frac == 0).all()                 # every case was exact-rank


def test_multilevel_one_bin_mass():
    """All mass in a single bit-pattern bin (constant rows): every level's
    bracketing bin holds the entire count and the resolved pattern is the
    constant itself, bit-equal, with ss = L·c²."""
    c = np.float32(3.14159)
    rows = jnp.full((2, 1024), c)
    t, ss = ml.row_trimmed_stats_multilevel(rows, jnp.asarray([0.95, 1.0]),
                                            interpret=True)
    np.testing.assert_array_equal(np.asarray(t), c)
    np.testing.assert_allclose(np.asarray(ss), 1024 * float(c) ** 2,
                               rtol=1e-5)


def test_multilevel_bf16_ties_across_bin_boundary():
    """bf16-cast rows tie heavily and pile up on byte-boundary bit
    patterns (a bf16 value's lower mantissa bytes are zero, landing ties
    exactly ON level boundaries): ranks must still resolve exactly."""
    rows = jax.random.normal(jax.random.PRNGKey(2), (3, 2048), jnp.float32) \
        .astype(jnp.bfloat16).astype(jnp.float32)
    _check_multilevel(rows, jnp.asarray([0.95, 0.9993, 1.0]))


def test_multilevel_all_zero_rows():
    """Fully masked rows: zero threshold and zero trimmed sum, not NaN."""
    rows = jnp.zeros((2, 1024))
    t, ss = ml.row_trimmed_stats_multilevel(rows, jnp.asarray([0.95, 1.0]),
                                            interpret=True)
    np.testing.assert_array_equal(np.asarray(t), 0.0)
    np.testing.assert_array_equal(np.asarray(ss), 0.0)


def test_multilevel_single_row_and_column_pad():
    """m = 1 with a non-tile-dividing length: the wrapper pads columns to
    the tile and marks them seg -1 — inert, never binned into segment 0."""
    rows = jax.random.normal(jax.random.PRNGKey(3), (1, 700), jnp.float32)
    _check_multilevel(rows, jnp.asarray([0.97]))


def test_multilevel_segmented_matches_per_segment_quantile():
    """The segment-aware entry point against per-segment jnp.quantile: one
    flat (m, C) slice holding three segments of different lengths, each
    with its own per-client level."""
    lens = (500, 260, 264)                   # sums to 2 * TILE
    C = sum(lens)
    assert C % ml.TILE == 0
    m, S = 2, len(lens)
    rows = jax.random.normal(jax.random.PRNGKey(4), (m, C), jnp.float32)
    seg_id = jnp.asarray(np.repeat(np.arange(S), lens).astype(np.int32))
    q_seg = jnp.asarray([[0.95, 1.0, 0.9737], [0.9871, 0.96, 1.0]],
                        jnp.float32)
    t, ss = ml.segmented_trimmed_stats(rows, seg_id,
                                       jnp.asarray(lens, jnp.int32), q_seg,
                                       interpret=True)
    t_np, ss_np = np.asarray(t), np.asarray(ss)
    start = 0
    for s, ln in enumerate(lens):
        seg = jnp.abs(rows[:, start:start + ln])
        ref = np.asarray(jax.vmap(jnp.quantile)(seg, q_seg[:, s]))
        lo = np.asarray(jax.vmap(
            lambda r, qq: jnp.quantile(r, qq, method="lower"))(seg, q_seg[:, s]))
        hi = np.asarray(jax.vmap(
            lambda r, qq: jnp.quantile(r, qq, method="higher"))(seg, q_seg[:, s]))
        assert (_ulp_dist(t_np[:, s], ref) <= 1).all()
        assert (t_np[:, s] >= lo).all() and (t_np[:, s] <= hi).all()
        a_np = np.asarray(seg, np.float32)
        ref_ss = np.where(a_np <= t_np[:, s][:, None],
                          a_np.astype(np.float64) ** 2, 0.0).sum(axis=1)
        np.testing.assert_allclose(ss_np[:, s], ref_ss, rtol=1e-5,
                                   atol=1e-7)
        start += ln


def test_dispatch_long_rows_take_multilevel_not_oracle():
    """ISSUE 9 bugfix pin: rows past the single-pass VMEM budget with the
    kernel path explicitly requested dispatch to the two-stage kernel —
    read-once, sort-free — NEVER to the jnp oracle (whose lowering sorts
    and re-reads the rows; see the companion contract test)."""
    from repro.analysis import jaxpr as jaxpr_mod
    L = 2 ** 18 + 512                        # Lp > _SINGLE_PASS_ELEMS
    rows = jax.random.normal(jax.random.PRNGKey(5), (2, L), jnp.float32)
    q = jnp.full((2,), 0.975, jnp.float32)
    c = jaxpr_mod.trace_counts(
        lambda r, qq: qops.row_trimmed_stats(r, qq, use_kernel=False,
                                             interpret=True),
        rows, q, row_elems=rows.size)
    assert (c.reads, c.sorts) == (1, 0)
    # and the result agrees with the multilevel path bit-for-bit
    t1, ss1 = qops.row_trimmed_stats(rows, q, use_kernel=False,
                                     interpret=True)
    t2, ss2 = ml.row_trimmed_stats_multilevel(rows, q, interpret=True)
    np.testing.assert_array_equal(np.asarray(t1), np.asarray(t2))
    np.testing.assert_array_equal(np.asarray(ss1), np.asarray(ss2))
