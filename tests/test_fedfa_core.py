"""FedFA core invariants: extraction equivalence, grafting, scaling,
aggregation identities, attack dilution."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_batch, tiny

from repro.core import fedfa
from repro.core.masking import (active_fraction, apply_mask_tree,
                                axis_mask_tree)
from repro.models import model as model_mod
from repro.models.masks import (ClientArch, depth_gates, full_client,
                                graft_map, max_section_depths, stack_masks,
                                width_masks, width_spec)


def _slice_like(small_tree, big_tree):
    return jax.tree.map(
        lambda s, b: b[tuple(slice(0, d) for d in s.shape)], small_tree, big_tree)


@pytest.mark.parametrize("arch,w", [
    ("smollm-135m", 0.5), ("tinyllama-1.1b", 0.25), ("codeqwen1.5-7b", 0.75),
])
def test_width_extraction_equals_small_dense_model(arch, w):
    """THE core property of the padded-dense design: a width-masked global
    model computes exactly what the corresponding small dense model does."""
    cfg = tiny(arch)
    spec = width_spec(cfg, w)
    small = cfg.replace(d_model=spec.d_model, n_heads=spec.n_heads,
                        n_kv_heads=spec.n_kv_heads, d_ff=spec.d_ff)
    params = model_mod.init_params(cfg, jax.random.PRNGKey(0))
    masks = width_masks(cfg, w)
    pm = apply_mask_tree(params, axis_mask_tree(cfg, masks))
    ps = _slice_like(model_mod.init_params(small, jax.random.PRNGKey(0)), pm)
    batch = make_batch(cfg)
    lg_small, _ = model_mod.forward(ps, small, batch, remat=False)
    lg_masked, _ = model_mod.forward(pm, cfg, batch, masks=masks, remat=False)
    assert float(jnp.abs(lg_small - lg_masked).max()) < 1e-4


def test_depth_gates_equal_shallow_model():
    cfg = tiny("smollm-135m").replace(n_layers=4, n_sections=2)
    params = model_mod.init_params(cfg, jax.random.PRNGKey(0))
    batch = make_batch(cfg)
    gates = depth_gates(cfg, (1, 2))
    lg_gated, _ = model_mod.forward(params, cfg, batch, gates=gates, remat=False)
    small = cfg.replace(n_layers=3, n_sections=1)
    sel = jnp.array([0, 2, 3])
    st = jax.tree.map(lambda b: jnp.take(b, sel, 0), params["stages"][0])
    ps = dict(params, stages=(st,))
    lg_small, _ = model_mod.forward(ps, small, batch, remat=False)
    assert float(jnp.abs(lg_small - lg_gated).max()) == 0.0


def test_graft_map_replicates_last_active():
    cfg = tiny("smollm-135m").replace(n_layers=2, n_sections=2)
    # sections [(0,1),(1,2)]; depths (1,1): identity
    assert graft_map(cfg, (1, 1)).tolist() == [0, 1]
    cfg8 = tiny("smollm-135m").replace(
        n_layers=2, n_sections=2).replace(n_layers=2)
    from repro.configs import get_arch
    full = get_arch("smollm-135m")          # 30 layers, 4 sections
    gm = graft_map(full, (2, 8, 3, 1))
    bounds = full.section_bounds()
    gm = np.asarray(gm)
    for (lo, hi), d in zip(bounds, (2, 8, 3, 1)):
        assert (gm[lo:lo + d] == np.arange(lo, lo + d)).all()
        assert (gm[lo + d:hi] == lo + d - 1).all()


def test_grafted_params_complete_aggregation():
    """After grafting, every depth position receives every client's update
    (gamma > 0 everywhere a width mask allows) — the security property."""
    cfg = tiny("smollm-135m").replace(n_layers=4, n_sections=2)
    params = model_mod.init_params(cfg, jax.random.PRNGKey(0))
    # one shallow full-width client only
    arch = ClientArch(1.0, (1, 1))
    stacked = jax.tree.map(lambda x: x[None], params)
    masks = stack_masks([arch.masks(cfg)])
    gates = jnp.stack([arch.gates(cfg)])
    gmaps = jnp.stack([arch.graft(cfg)])
    nd = jnp.ones((1,))
    out_graft = fedfa.aggregate(params, stacked, cfg, masks, gates, gmaps, nd,
                                graft=True, scale=False)
    out_part = fedfa.aggregate(params, stacked, cfg, masks, gates, gmaps, nd,
                               graft=False, scale=False)
    # grafted: depth slot 1 of section 0 now equals slot 0 (replicated)
    wq = out_graft["stages"][0][0]["attn"]["wq"]
    assert float(jnp.abs(wq[1] - wq[0]).max()) == 0.0
    # partial: depth slot 1 untouched (kept global value)
    wq_p = out_part["stages"][0][0]["attn"]["wq"]
    assert float(jnp.abs(wq_p[1] - params["stages"][0][0]["attn"]["wq"][1]).max()) == 0.0


def test_scaling_factors_normalize_scale_variation():
    """Client with 2x-scaled weights is normalized back (alpha ~ mean/norm)."""
    cfg = tiny("smollm-135m").replace(n_layers=2, n_sections=1)
    p1 = model_mod.init_params(cfg, jax.random.PRNGKey(1))
    p2 = jax.tree.map(lambda x: 2.0 * x, p1)
    stacked = jax.tree.map(lambda a, b: jnp.stack([a, b]), p1, p2)
    fc = full_client(cfg)
    masks = stack_masks([fc.masks(cfg)] * 2)
    gates = jnp.stack([fc.gates(cfg)] * 2)
    gmaps = jnp.stack([fc.graft(cfg)] * 2)
    nd = jnp.ones((2,))
    out = fedfa.aggregate(p1, stacked, cfg, masks, gates, gmaps, nd,
                          graft=True, scale=True)
    # scalable aggregation: both clients rescaled to the mean norm 1.5x, so
    # result == 1.5 * p1 (both clients' directions identical)
    ref = jax.tree.map(lambda x: 1.5 * x, p1)
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(ref)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-2,
                                   atol=1e-4)
    # without scaling: plain mean = 1.5 * p1 as well — distinguish via norms
    norms = fedfa.trimmed_sq_norms(p2, axis_mask_tree(cfg, fc.masks(cfg)))
    assert all(float(x.min()) >= 0 for x in jax.tree.leaves(norms))


def test_trimmed_norm_masked_quantile_correction():
    """95th percentile over ACTIVE entries only (zero-padding corrected)."""
    cfg = tiny("smollm-135m")
    masks = width_masks(cfg, 0.5)
    ax = axis_mask_tree(cfg, masks)
    w = jax.random.normal(jax.random.PRNGKey(0), (cfg.d_model, cfg.d_ff))
    axl = ax["stages"][0][0]["ffn"]["w_gate"]
    f = active_fraction(axl)
    # emulate: quantile over active == shifted quantile over masked-full
    from repro.core.masking import _apply_ax
    wm = _apply_ax(w, axl)
    active = np.asarray(wm)[np.asarray(wm) != 0.0]
    q_direct = np.quantile(np.abs(active), 0.95)
    q_shift = np.quantile(np.abs(np.asarray(wm)), 1 - 0.05 * float(f))
    assert abs(q_direct - q_shift) / q_direct < 0.02


def test_attack_dilution_with_grafting():
    """A malicious deep-slot update is diluted by grafting (complete
    aggregation) but survives partial aggregation — Fig. 1's weak point."""
    cfg = tiny("smollm-135m").replace(n_layers=4, n_sections=2)
    g = model_mod.init_params(cfg, jax.random.PRNGKey(0))
    n = 8
    shallow = ClientArch(1.0, (1, 1))          # honest: depth slots 0, 2
    attacker = full_client(cfg)                # malicious: all 4 slots
    specs = [shallow] * (n - 1) + [attacker]
    clients = []
    for i, a in enumerate(specs):
        if i < n - 1:
            clients.append(jax.tree.map(lambda x: x, g))   # no-op update
        else:
            clients.append(jax.tree.map(lambda x: x + 10.0, g))  # poisoned
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *clients)
    masks = stack_masks([a.masks(cfg) for a in specs])
    gates = jnp.stack([a.gates(cfg) for a in specs])
    gmaps = jnp.stack([a.graft(cfg) for a in specs])
    nd = jnp.ones((n,))

    part = fedfa.aggregate(g, stacked, cfg, masks, gates, gmaps, nd,
                           graft=False, scale=False)
    graft = fedfa.aggregate(g, stacked, cfg, masks, gates, gmaps, nd,
                            graft=True, scale=False)
    # weak-point weight: depth slot 1 (only the attacker holds it)
    tgt = lambda t: t["stages"][0][0]["attn"]["wq"]
    dev_part = float(jnp.abs(tgt(part)[1] - tgt(g)[1]).mean())
    dev_graft = float(jnp.abs(tgt(graft)[1] - tgt(g)[1]).mean())
    assert dev_part > 9.9          # attacker fully owns the weak point
    assert dev_graft < dev_part / 4  # grafting dilutes it ~n-fold
