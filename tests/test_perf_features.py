"""Tests for the §Perf machinery: head padding, window block-skip,
fused momentum accumulation, vocab padding."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tiny

from repro.configs import get_arch
from repro.launch.steps import make_train_step
from repro.models import model as model_mod
from repro.models.attention import _attend_dense, attend_blocked
from repro.optim import init_opt
from repro.sharding.padding import pad_heads_for_serving


def _place_params(small_p, big_p):
    """Copy small params into the zero-padded big tree (prefix placement)."""
    flat_b = jax.tree_util.tree_flatten_with_path(big_p)[0]
    flat_s = dict(jax.tree_util.tree_flatten_with_path(small_p)[0])
    leaves = []
    for path, b in flat_b:
        s = flat_s[path]
        z = jnp.zeros_like(b)
        leaves.append(z.at[tuple(slice(0, d) for d in s.shape)].set(s))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(big_p), leaves)


def test_head_padding_preserves_decode():
    cfg = tiny("smollm-135m")            # H=4, K=2
    p = model_mod.init_params(cfg, jax.random.PRNGKey(0))
    cfg2, masks = pad_heads_for_serving(cfg, axis=8)
    assert cfg2.n_kv_heads == 8 and masks is not None
    p2 = _place_params(p, model_mod.init_params(cfg2, jax.random.PRNGKey(1)))
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 12), 0, cfg.vocab_size)
    lg1, c1, _ = model_mod.prefill(p, cfg, {"tokens": toks[:, :8]},
                                   capacity=16, cache_dtype=jnp.float32)
    lg2, c2, _ = model_mod.prefill(p2, cfg2, {"tokens": toks[:, :8]},
                                   capacity=16, masks=masks,
                                   cache_dtype=jnp.float32)
    assert float(jnp.abs(lg1 - lg2).max()) < 1e-4
    for i in range(8, 12):
        lg1, c1 = model_mod.decode_step(p, cfg, toks[:, i:i + 1], c1)
        lg2, c2 = model_mod.decode_step(p2, cfg2, toks[:, i:i + 1], c2,
                                        masks=masks)
    assert float(jnp.abs(lg1 - lg2).max()) < 1e-4


def test_head_padding_noop_when_divisible():
    cfg = tiny("whisper-base")           # reduced: K=2 -> axis 2 divides
    cfg2, masks = pad_heads_for_serving(cfg, axis=cfg.n_kv_heads)
    assert masks is None and cfg2 is cfg
    full = get_arch("codeqwen1.5-7b")    # K=32 divides 16
    cfg3, masks3 = pad_heads_for_serving(full, axis=16)
    assert masks3 is None and cfg3 is full


@pytest.mark.parametrize("S,win,bq,bk", [(512, 100, 64, 64),
                                         (768, 64, 128, 64),
                                         (640, 300, 64, 128)])
def test_window_block_skip_exact(S, win, bq, bk):
    ks = jax.random.split(jax.random.PRNGKey(S), 3)
    q = jax.random.normal(ks[0], (1, S, 4, 32))
    k = jax.random.normal(ks[1], (1, S, 2, 32))
    v = jax.random.normal(ks[2], (1, S, 2, 32))
    o1 = attend_blocked(q, k, v, causal=True, window=win, bq=bq, bk=bk)
    o2 = _attend_dense(q, k, v, causal=True, window=win)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-5)


def test_fused_sgd_accumulation_matches_reference():
    """Fused momentum accumulation == explicit grad-accumulate + SGD."""
    cfg = tiny("smollm-135m").replace(optimizer="sgd", grad_accum=4,
                                      schedule="constant", learning_rate=0.05)
    params = model_mod.init_params(cfg, jax.random.PRNGKey(0))
    opt = init_opt(params, "sgd")
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0,
                                          cfg.vocab_size)}
    fused = make_train_step(cfg, total_steps=10)
    p1, o1, l1 = jax.jit(fused)(params, opt, batch, jnp.asarray(5))

    # reference: mean grad over microbatches, then classic sgd_momentum
    from repro.optim import opt_update
    micro = jax.tree.map(lambda x: x.reshape((4, 2) + x.shape[1:]), batch)
    grads = None
    for i in range(4):
        mb = jax.tree.map(lambda x: x[i], micro)
        g = jax.grad(lambda pp: model_mod.loss_fn(pp, cfg, mb, task="lm")[0])(params)
        grads = g if grads is None else jax.tree.map(jnp.add, grads, g)
    grads = jax.tree.map(lambda g: g / 4, grads)
    p2, o2 = opt_update("sgd", params, grads, opt, 0.05,
                        momentum=cfg.momentum, weight_decay=cfg.weight_decay)
    errs = [float(jnp.abs(a - b).max())
            for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2))]
    assert max(errs) < 1e-5, max(errs)


def test_vocab_padding_masks_logits():
    cfg = tiny("smollm-135m").replace(vocab_size=100)   # pads to 128
    assert cfg.padded_vocab == 128
    p = model_mod.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, 100)
    logits, _ = model_mod.forward(p, cfg, {"tokens": toks}, remat=False)
    assert logits.shape[-1] == 128
    assert float(logits[..., 100:].max()) <= -1e29   # padding masked
    # loss is finite and ignores padding
    loss, _ = model_mod.loss_fn(p, cfg, {"tokens": toks})
    assert jnp.isfinite(loss)
