"""Deliberately-planted lint violations for ``tests/test_analysis.py``.

NOT collected by pytest (no ``test_`` prefix) and never imported — the
lint tests read it by path.  One violation per rule: a module-level jnp
call (import-time-jnp), a ``jax.random.split`` inside a jitted function
(traced-random-split), and input validation via ``assert`` (bare-assert).
"""
import functools

import jax
import jax.numpy as jnp

_BAD_CONSTANT = jnp.zeros((4,))  # initializes the backend at import


@functools.partial(jax.jit, static_argnums=(1,))
def bad_round_step(key, n):
    keys = jax.random.split(key, n)  # traced split: threefry-parity bug
    return keys


def bad_validate(w):
    assert 0.0 < w <= 1.0, "width out of range"
    return w
