"""Planted lint fixture: host syncs on traced values inside a jitted
program (the PR 6 incremental-loss-conversion bug class).  NEVER import
this module — ``tests/test_analysis.py`` feeds its source to the linter
and asserts the ``host-sync-in-program`` findings below (and that the
``# noqa`` escape suppresses one)."""
import jax
import jax.numpy as jnp
import numpy as np


def make_bad_program(index):
    def _round(g_buf, losses):
        total = float(losses.sum())          # BAD: host sync at trace time
        mean = losses.mean().item()          # BAD: .item() on traced value
        snap = np.asarray(g_buf)             # BAD: device->host copy
        ok = np.asarray(losses)  # noqa: host-sync-in-program
        return g_buf * total + mean + snap.shape[0] + ok.shape[0]

    return jax.jit(_round, donate_argnums=(0,))


def host_side_is_fine(losses):
    # NOT jitted: converting on program outputs is exactly the fix
    return float(np.asarray(losses).mean())
