"""Substrate tests: data, optimizers, schedules, checkpointing, serving."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_batch, tiny

from repro.checkpoint import checkpoint as ckpt
from repro.data import partition, pipeline, synthetic
from repro.models import model as model_mod
from repro.optim import init_opt, make_schedule, opt_update


def test_lm_stream_learnable_structure():
    """Bigram structure: conditional entropy < marginal entropy."""
    toks = synthetic.lm_stream(64, 200, 64, seed=0)
    flat = toks.reshape(-1)
    marg = np.bincount(flat, minlength=64) / flat.size
    h_marg = -np.sum(marg * np.log(marg + 1e-12))
    # conditional on previous token
    joint = np.zeros((64, 64))
    for row in toks:
        for a, b in zip(row[:-1], row[1:]):
            joint[a, b] += 1
    cond = joint / np.maximum(joint.sum(1, keepdims=True), 1)
    pprev = joint.sum(1) / joint.sum()
    h_cond = -np.sum(pprev[:, None] * cond * np.log(cond + 1e-12))
    assert h_cond < h_marg - 0.2


def test_classification_separable():
    prof = synthetic.make_class_profiles(4, 64, seed=0)
    d = synthetic.classification(4, 64, 200, 32, profiles=prof, seed=1)
    # naive bayes with the true profiles should classify well
    logp = np.log(prof + 1e-9)
    scores = logp[:, d["tokens"]].sum(-1)      # (C, N)
    acc = (scores.argmax(0) == d["labels"]).mean()
    assert acc > 0.9


def test_noniid_partition_class_coverage():
    parts = partition.noniid_partition(50, 10, class_frac=0.2, seed=0)
    for p in parts:
        assert len(p["classes"]) == 2
        assert p["class_mask"].sum() == 2
    iid = partition.iid_partition(10, 10, n_data_range=(100, 250), seed=0)
    nd = [p["n_data"] for p in iid]
    assert min(nd) >= 100 and max(nd) < 250


def test_sgd_momentum_matches_manual():
    p = {"w": jnp.ones((4,))}
    g = {"w": jnp.full((4,), 2.0)}
    st = init_opt(p, "sgd")
    p1, st = opt_update("sgd", p, g, st, 0.1, momentum=0.9, weight_decay=0.0)
    np.testing.assert_allclose(np.asarray(p1["w"]), 1 - 0.1 * 2.0)
    p2, st = opt_update("sgd", p1, g, st, 0.1, momentum=0.9, weight_decay=0.0)
    # m2 = 0.9*2 + 2 = 3.8
    np.testing.assert_allclose(np.asarray(p2["w"]), 0.8 - 0.38, rtol=1e-6)


def test_adamw_step_finite_and_decays():
    p = {"w": jnp.ones((8,))}
    g = {"w": jnp.zeros((8,))}
    st = init_opt(p, "adamw")
    p1, _ = opt_update("adamw", p, g, st, 0.1, weight_decay=0.5)
    assert float(p1["w"][0]) < 1.0             # pure weight decay shrinks


@pytest.mark.parametrize("name", ["constant", "step", "cosine", "wsd"])
def test_schedules_shape(name):
    s = make_schedule(name, 0.1, 100, warmup=10)
    vals = [float(s(jnp.asarray(t))) for t in [0, 10, 50, 99]]
    assert all(v >= 0 for v in vals)
    assert max(vals) <= 0.1 + 1e-6
    if name in ("cosine", "wsd"):
        assert vals[0] == 0.0                  # warmup from zero
    if name == "step":
        assert vals[-1] < vals[0]


def test_checkpoint_roundtrip(tmp_path):
    cfg = tiny("smollm-135m")
    params = model_mod.init_params(cfg, jax.random.PRNGKey(0))
    path = os.path.join(tmp_path, "ck")
    ckpt.save(path, params, meta={"step": 7})
    like = model_mod.init_params(cfg, jax.random.PRNGKey(1))
    restored, meta = ckpt.restore(path, like)
    assert meta["step"] == 7
    for a, b in zip(jax.tree.leaves(restored), jax.tree.leaves(params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_serve_engine_greedy_deterministic():
    from repro.launch.serve import Engine
    cfg = tiny("smollm-135m")
    params = model_mod.init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, capacity=64)
    prompts = synthetic.lm_stream(cfg.vocab_size, 2, 16, seed=0)
    o1 = eng.generate(prompts, max_new=8)
    o2 = eng.generate(prompts, max_new=8)
    assert o1.shape == (2, 8)
    np.testing.assert_array_equal(o1, o2)


def test_nas_zico_and_search():
    from repro.core.nas import SearchSpace, evolutionary_search, zico_score
    from repro.models.masks import ClientArch, max_section_depths
    cfg = tiny("smollm-135m").replace(vocab_size=64)
    params = model_mod.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (3, 2, 16), 0, 64)
    batches = {"tokens": toks}
    s1 = zico_score(cfg, ClientArch(1.0, max_section_depths(cfg)), params, batches)
    assert np.isfinite(s1)
    best = evolutionary_search(cfg, params, batches, population=4,
                               generations=1, seed=0)
    assert 0 < best.width_mult <= 1.0
    assert all(d >= 1 for d in best.section_depths)
