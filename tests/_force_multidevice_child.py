"""Subprocess body for the multi-device sharded-round tests.

Runs under ``XLA_FLAGS=--xla_force_host_platform_device_count=4`` (set by
the parent test — the flag is read at jax init, so it cannot be toggled
inside the main pytest process): sharded-vs-unsharded resident parity for
fedfa + heterofl on an UNEVEN m=3 cohort over 4 devices (one pad row,
``n_data = 0``) with a malicious client, plus buffer donation under
NamedSharding.  Prints ``MULTIDEVICE OK`` on success.
"""
import jax
import numpy as np

# the parent test adds tests/ to the child's PYTHONPATH
from conftest import assert_tree_allclose, fl_round_fixture, make_cohort

from repro.core import flat
from repro.core import round as round_mod
from repro.core.server import FLConfig, stack_runtimes
from repro.launch.mesh import make_data_mesh
from repro.sharding import cohort as csh

assert jax.device_count() == 4, \
    f"expected 4 forced host devices, got {jax.device_count()}"

CFG, PARAMS = fl_round_fixture()
M, E = 3, 2
KEY = jax.random.PRNGKey(0)
SPECS, data_fn = make_cohort(CFG, M, local_steps=E, malicious_frac=0.34)
assert any(s.malicious for s in SPECS), "cohort must include an attacker"
MESH = make_data_mesh()
assert MESH.shape["data"] == 4


# --- parity: m=3 cohort padded to 4 shards must match the unsharded round
for strategy in ("fedfa", "heterofl"):
    fl = FLConfig(local_steps=E, lr=0.05, strategy=strategy, task="cls",
                  agg_engine="flat")
    p_un, l_un = round_mod.run_rounds(PARAMS, CFG, fl, 2, data_fn, KEY,
                                      eval_every=0)
    p_sh, l_sh = round_mod.run_rounds(PARAMS, CFG, fl, 2, data_fn, KEY,
                                      eval_every=0, mesh=MESH)
    np.testing.assert_allclose(l_un, l_sh, rtol=1e-4)
    assert_tree_allclose(p_un, p_sh)
    print(f"parity {strategy}: OK")

# --- donation still effective under NamedSharding (program cached above)
fl = FLConfig(local_steps=E, lr=0.05, strategy="fedfa", task="cls",
              agg_engine="flat")
index = flat.get_index(PARAMS)
runtimes = stack_runtimes(CFG, SPECS)
_, batches = data_fn(0)
g_buf = jax.device_put(flat.flatten(index, PARAMS), csh.replicated(MESH))
g2, c2, _ = round_mod.flat_round(g_buf, None, CFG, fl, index, runtimes,
                                 batches, KEY, mesh=MESH, any_malicious=True)
assert g_buf.is_deleted(), "donated global buffer not consumed"
assert c2.shape == (4, index.n), c2.shape          # padded to the 4 shards
assert c2.sharding.spec == jax.sharding.PartitionSpec("data")
g3, c3, _ = round_mod.flat_round(g2, c2, CFG, fl, index, runtimes, batches,
                                 KEY, mesh=MESH, any_malicious=True)
assert g2.is_deleted() and c2.is_deleted(), \
    "ping-pong donation broken under NamedSharding"
assert not (g3.is_deleted() or c3.is_deleted())
print("donation: OK")

print("MULTIDEVICE OK")
