"""Subprocess body for the multi-device sharded-round tests.

Runs under ``XLA_FLAGS=--xla_force_host_platform_device_count=4`` (set by
the parent test — the flag is read at jax init, so it cannot be toggled
inside the main pytest process): sharded-vs-unsharded resident parity for
fedfa + heterofl on an UNEVEN m=3 cohort over 4 devices (one pad row,
``n_data = 0``) with a malicious client, plus buffer donation under
NamedSharding.  Prints ``MULTIDEVICE OK`` on success.

With ``--quantile-collectives`` it instead lowers the KERNELIZED flat
aggregation (fused Pallas trimmed-quantile pass, interpret mode) under the
4-device mesh and asserts the collective structure is unchanged: zero
all-gathers and <= 2 N-sized all-reduces (the two (M', γ) psums).  Prints
``QUANTILE COLLECTIVES OK``.
"""
import sys

import jax
import numpy as np

# the parent test adds tests/ to the child's PYTHONPATH
from conftest import assert_tree_allclose, fl_round_fixture, make_cohort

from repro.core import flat
from repro.core import round as round_mod
from repro.core.server import FLConfig, stack_runtimes
from repro.launch.mesh import make_data_mesh
from repro.sharding import cohort as csh

assert jax.device_count() == 4, \
    f"expected 4 forced host devices, got {jax.device_count()}"

CFG, PARAMS = fl_round_fixture()
M, E = 3, 2
KEY = jax.random.PRNGKey(0)
SPECS, data_fn = make_cohort(CFG, M, local_steps=E, malicious_frac=0.34)
assert any(s.malicious for s in SPECS), "cohort must include an attacker"
MESH = make_data_mesh()
assert MESH.shape["data"] == 4


if "--quantile-collectives" in sys.argv:
    import re

    import jax.numpy as jnp

    index = flat.get_index(PARAMS)
    runtimes = stack_runtimes(CFG, SPECS)
    pad = csh.pad_rows(M, MESH)
    (masks, gates, gmaps, nd, _, _), _ = csh.pad_cohort(
        runtimes, {"d": jnp.zeros((M, 1))}, pad)
    g = jax.device_put(flat.flatten(index, PARAMS), csh.replicated(MESH))
    x = jax.device_put(
        jax.random.normal(KEY, (M + pad, index.n), jnp.float32),
        csh.cohort_sharding(MESH))

    fn = jax.jit(lambda g, x, nd: flat.aggregate_buffers(
        index, g, x, CFG, masks, gates, gmaps, nd, graft=True, scale=True,
        use_kernel=True, interpret=True, mesh=MESH))
    txt = fn.lower(g, x, nd).compile().as_text()

    n_gather = len(re.findall(r"\sall-gather(?:-start)?\(", txt))
    assert n_gather == 0, \
        f"{n_gather} all-gather(s) in the kernelized aggregation"
    shape_re = re.compile(r"=\s*\(?([a-z0-9]+)\[([\d,]*)\]")
    n_psum = 0
    for line in txt.splitlines():
        if " all-reduce(" not in line and " all-reduce-start(" not in line:
            continue
        sm = shape_re.search(line)
        dims = [int(d) for d in sm.group(2).split(",") if d] if sm else []
        elems = 1
        for d in dims:
            elems *= d
        if elems == index.n:
            n_psum += 1
    assert 1 <= n_psum <= 2, \
        f"expected 1-2 N-sized all-reduces (the (M', γ) psums), got {n_psum}"
    print(f"collectives: all-gather=0 n-sized-all-reduce={n_psum}")
    print("QUANTILE COLLECTIVES OK")
    sys.exit(0)


# --- parity: m=3 cohort padded to 4 shards must match the unsharded round
for strategy in ("fedfa", "heterofl"):
    fl = FLConfig(local_steps=E, lr=0.05, strategy=strategy, task="cls",
                  agg_engine="flat")
    p_un, l_un = round_mod.run_rounds(PARAMS, CFG, fl, 2, data_fn, KEY,
                                      eval_every=0)
    p_sh, l_sh = round_mod.run_rounds(PARAMS, CFG, fl, 2, data_fn, KEY,
                                      eval_every=0, mesh=MESH)
    np.testing.assert_allclose(l_un, l_sh, rtol=1e-4)
    assert_tree_allclose(p_un, p_sh)
    print(f"parity {strategy}: OK")

# --- donation still effective under NamedSharding (program cached above)
fl = FLConfig(local_steps=E, lr=0.05, strategy="fedfa", task="cls",
              agg_engine="flat")
index = flat.get_index(PARAMS)
runtimes = stack_runtimes(CFG, SPECS)
_, batches = data_fn(0)
g_buf = jax.device_put(flat.flatten(index, PARAMS), csh.replicated(MESH))
g2, c2, _ = round_mod.flat_round(g_buf, None, CFG, fl, index, runtimes,
                                 batches, KEY, mesh=MESH, any_malicious=True)
assert g_buf.is_deleted(), "donated global buffer not consumed"
assert c2.shape == (4, index.n), c2.shape          # padded to the 4 shards
assert c2.sharding.spec == jax.sharding.PartitionSpec("data")
g3, c3, _ = round_mod.flat_round(g2, c2, CFG, fl, index, runtimes, batches,
                                 KEY, mesh=MESH, any_malicious=True)
assert g2.is_deleted() and c2.is_deleted(), \
    "ping-pong donation broken under NamedSharding"
assert not (g3.is_deleted() or c3.is_deleted())
print("donation: OK")

print("MULTIDEVICE OK")
