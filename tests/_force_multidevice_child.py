"""Subprocess body for the multi-device sharded-round tests.

Runs under ``XLA_FLAGS=--xla_force_host_platform_device_count=4`` (set by
the parent test — the flag is read at jax init, so it cannot be toggled
inside the main pytest process): sharded-vs-unsharded resident parity for
fedfa + heterofl on an UNEVEN m=3 cohort over 4 devices (one pad row,
``n_data = 0``) with a malicious client, plus buffer donation under
NamedSharding.  Prints ``MULTIDEVICE OK`` on success.

With ``--quantile-collectives`` it instead lowers the KERNELIZED flat
aggregation (fused Pallas trimmed-quantile pass, interpret mode) under the
4-device data mesh and asserts the collective structure is unchanged: zero
all-gathers and <= 2 N-sized all-reduces (the two (M', γ) psums).  Prints
``QUANTILE COLLECTIVES OK``.

With ``--two-d`` it runs the 2x2 ``(data, model)`` cases instead: resident
parity vs the unsharded round (fedfa + heterofl, uneven m=3, malicious
client), N-pad-segment inertness (a ``FlatIndex`` whose ``pad_to`` does NOT
divide N, driven through the full round: pads stay zero and never leak into
norms, α, or the merged global), resident buffers materially model-sharded
(N/2 per device) with ping-pong donation, and a checkpoint roundtrip from /
to the model-sharded global layout.  Prints ``TWO-D OK``.

With ``--agg-collectives-2d`` it lowers the kernelized aggregation under
the 2x2 mesh and asserts the distributed two-stage structure (ISSUE 9):
ZERO all-gathers, ZERO reduce-scatters (the N axis splits early — nothing
N-wide survives to scatter), and every all-reduce bounded by
max(N/2, histogram planes) — per-device volume ~N/n_model.  Prints
``AGG COLLECTIVES 2D OK``.

With ``--async`` it runs the async engine under the 4-device data mesh:
parity-mode bit-equality with the sharded ``run_rounds`` (fedfa +
heterofl), skewed-trace bounded-staleness merges, the declared merge AND
admit contracts on the lowered programs (zero all-gathers in both — the
admit is a slot-order select since PR 8 — plus the peak-live-bytes
budgets), and the ``ResidentDriver._cbufs`` padded-key regression.
Prints ``ASYNC OK``.

With ``--quant`` it runs the quantized-admission cases under the 4-device
data mesh: bf16/int8 sharded rounds stay within quantization drift of the
sharded f32 round, and the ``ResidentDriver._cbufs`` dtype-key regression
— one driver serving f32 AND int8 cohorts of the same padded size holds
one pool per admission dtype and never donates across dtypes.  Prints
``QUANT OK``.
"""
import sys

import jax
import numpy as np

# the parent test adds tests/ to the child's PYTHONPATH
from conftest import assert_tree_allclose, fl_round_fixture, make_cohort

from repro.core import flat
from repro.core import round as round_mod
from repro.core.server import FLConfig, stack_runtimes
from repro.launch.mesh import make_data_mesh, make_mesh_2d
from repro.sharding import cohort as csh

assert jax.device_count() == 4, \
    f"expected 4 forced host devices, got {jax.device_count()}"

CFG, PARAMS = fl_round_fixture()
M, E = 3, 2
KEY = jax.random.PRNGKey(0)
SPECS, data_fn = make_cohort(CFG, M, local_steps=E, malicious_frac=0.34)
assert any(s.malicious for s in SPECS), "cohort must include an attacker"
MESH = make_data_mesh()
assert MESH.shape["data"] == 4


def _fl(strategy):
    return FLConfig(local_steps=E, lr=0.05, strategy=strategy, task="cls",
                    agg_engine="flat")


if "--quantile-collectives" in sys.argv:
    import jax.numpy as jnp

    index = flat.get_index(PARAMS)
    runtimes = stack_runtimes(CFG, SPECS)
    pad = csh.pad_rows(M, MESH)
    (masks, gates, gmaps, nd, _, _), _ = csh.pad_cohort(
        runtimes, {"d": jnp.zeros((M, 1))}, pad)
    g = jax.device_put(flat.flatten(index, PARAMS), csh.replicated(MESH))
    x = jax.device_put(
        jax.random.normal(KEY, (M + pad, index.n), jnp.float32),
        csh.cohort_sharding(MESH))

    fn = jax.jit(lambda g, x, nd: flat.aggregate_buffers(
        index, g, x, CFG, masks, gates, gmaps, nd, graft=True, scale=True,
        use_kernel=True, interpret=True, mesh=MESH))
    txt = fn.lower(g, x, nd).compile().as_text()

    from repro.kernels.fedfa_agg.ops import accumulate_contract
    rep = accumulate_contract(index.n_padded, MESH,
                              rows=M + pad).check(hlo=txt)
    assert rep.ok, rep.violations
    assert rep.measured["peak_live_bytes_per_device"] > 0
    n_psum = rep.measured["scale_allreduces"]
    print(f"collectives: all-gather=0 n-sized-all-reduce={n_psum} "
          f"peak={rep.measured['peak_live_bytes_per_device']}B")
    print("QUANTILE COLLECTIVES OK")
    sys.exit(0)


if "--agg-collectives-2d" in sys.argv:
    import jax.numpy as jnp

    mesh = make_mesh_2d(2, 2)
    index = flat.get_index(PARAMS, pad_to=csh.pad_unit(mesh))
    runtimes = stack_runtimes(CFG, SPECS)
    pad = csh.pad_rows(M, mesh)
    (masks, gates, gmaps, nd, _, _), _ = csh.pad_cohort(
        runtimes, {"d": jnp.zeros((M, 1))}, pad)
    g = jax.device_put(flat.flatten(index, PARAMS), csh.global_sharding(mesh))
    x = jax.device_put(
        jax.random.normal(KEY, (M + pad, index.n_padded), jnp.float32),
        csh.cohort_sharding(mesh))
    fn = jax.jit(lambda g, x, nd: flat.aggregate_buffers(
        index, g, x, CFG, masks, gates, gmaps, nd, graft=True, scale=True,
        use_kernel=True, interpret=True, mesh=mesh),
        out_shardings=csh.global_sharding(mesh))
    txt = fn.lower(g, x, nd).compile().as_text()
    from repro.kernels.fedfa_agg.ops import accumulate_contract
    rep = accumulate_contract(index.n_padded, mesh, rows=M + pad,
                              segs=index.n_segments).check(hlo=txt)
    assert rep.ok, rep.violations
    assert rep.measured["peak_live_bytes_per_device"] > 0
    assert rep.measured["reduce_scatters"] == 0
    n_half_ars = rep.measured["scale_allreduces"]
    print(f"collectives 2d: all-gather=0 reduce-scatter=0 "
          f"n/2-all-reduce={n_half_ars} "
          f"peak={rep.measured['peak_live_bytes_per_device']}B")
    print("AGG COLLECTIVES 2D OK")
    sys.exit(0)


if "--two-d" in sys.argv:
    import jax.numpy as jnp

    mesh = make_mesh_2d(2, 2)
    assert csh.model_shards(mesh) == 2 and csh.data_shards(mesh) == 2

    # --- parity: padded (m=3 -> 4 over 2 data shards) 2-D round == unsharded
    for strategy in ("fedfa", "heterofl"):
        fl = _fl(strategy)
        p_un, l_un = round_mod.run_rounds(PARAMS, CFG, fl, 2, data_fn, KEY,
                                          eval_every=0)
        p_sh, l_sh = round_mod.run_rounds(PARAMS, CFG, fl, 2, data_fn, KEY,
                                          eval_every=0, mesh=mesh)
        np.testing.assert_allclose(l_un, l_sh, rtol=1e-4)
        assert_tree_allclose(p_un, p_sh)
        print(f"2d parity {strategy}: OK")

    # --- N-pad inertness through the FULL round: a pad_to that does NOT
    # divide N forces a real inert tail; the padded 2-D round must match the
    # unpadded unsharded round and keep the tail exactly zero
    fl = _fl("fedfa")
    index_un = flat.get_index(PARAMS)
    pad_to = 1024
    index_p = flat.get_index(PARAMS, pad_to=pad_to)
    assert index_p.n_padded > index_p.n, \
        f"fixture N {index_p.n} divisible by {pad_to}; pick another pad_to"
    assert index_p.n_padded % csh.model_shards(mesh) == 0
    runtimes = stack_runtimes(CFG, SPECS)
    _, batches = data_fn(0)
    g_un, _, _ = round_mod.flat_round(
        flat.flatten(index_un, PARAMS), None, CFG, fl, index_un, runtimes,
        batches, KEY, any_malicious=True)
    g_buf = jax.device_put(flat.flatten(index_p, PARAMS),
                           csh.global_sharding(mesh))
    g_p, c_p, _ = round_mod.flat_round(g_buf, None, CFG, fl, index_p,
                                       runtimes, batches, KEY, mesh=mesh,
                                       any_malicious=True)
    g_p_host = np.asarray(jax.device_get(g_p))
    np.testing.assert_allclose(g_p_host[:index_un.n], np.asarray(g_un),
                               rtol=2e-4, atol=2e-5)
    np.testing.assert_array_equal(g_p_host[index_p.n:], 0.0)
    # the pad tail is outside every norm segment: α (hence the merged
    # global) must be identical whether or not the tail exists — already
    # implied by the parity above; additionally the tail never acquires
    # mass from the cohort buffer
    c_host = np.asarray(jax.device_get(c_p))
    assert c_host.shape == (4, index_p.n_padded)
    print("2d n-pad inertness: OK")

    # --- resident buffers are materially model-sharded + donation ping-pong
    assert g_p.sharding.spec == jax.sharding.PartitionSpec("model")
    assert c_p.sharding.spec == jax.sharding.PartitionSpec("data", "model")
    g_bytes = {s.data.nbytes for s in g_p.addressable_shards}
    assert g_bytes == {index_p.n_padded // 2 * 4}, g_bytes
    c_bytes = {s.data.nbytes for s in c_p.addressable_shards}
    assert c_bytes == {2 * (index_p.n_padded // 2) * 4}, c_bytes
    g2, c2, _ = round_mod.flat_round(g_p, c_p, CFG, fl, index_p, runtimes,
                                     batches, KEY, mesh=mesh,
                                     any_malicious=True)
    assert g_p.is_deleted() and c_p.is_deleted(), \
        "ping-pong donation broken under the 2-D NamedShardings"
    assert not (g2.is_deleted() or c2.is_deleted())
    print("2d donation + per-device bytes: OK")

    # --- checkpoint roundtrip from / to the model-sharded global layout
    import tempfile

    from repro.checkpoint import checkpoint as ckpt_mod
    with tempfile.TemporaryDirectory() as td:
        path = f"{td}/ck2d"
        ckpt_mod.save_from_buffer(path, index_p, g2, meta={"round": 1})
        idx_r, buf_r, meta = ckpt_mod.restore_to_buffer(path, PARAMS,
                                                        mesh=mesh)
        assert meta["round"] == 1 and meta["flat_n"] == index_p.n
        assert idx_r.n_padded % csh.model_shards(mesh) == 0
        assert buf_r.sharding.spec == jax.sharding.PartitionSpec("model")
        g2_host = np.asarray(jax.device_get(g2))
        np.testing.assert_array_equal(
            np.asarray(jax.device_get(buf_r))[:idx_r.n], g2_host[:idx_r.n])
    print("2d checkpoint roundtrip: OK")

    print("TWO-D OK")
    sys.exit(0)


if "--async" in sys.argv:
    import jax.numpy as jnp

    from repro.core.async_round import AsyncConfig, run_async
    from repro.sim import ParitySource, TraceSource

    # --- async parity under the 4-device data mesh: the fast path
    # dispatches the SAME sharded resident program run_rounds uses, so the
    # two drivers must be bit-equal even on the padded uneven cohort
    for strategy in ("fedfa", "heterofl"):
        fl = _fl(strategy)
        p_sync, l_sync = round_mod.run_rounds(PARAMS, CFG, fl, 2, data_fn,
                                              KEY, eval_every=0, mesh=MESH)
        p_async, l_async = run_async(PARAMS, CFG, fl, 2,
                                     ParitySource(data_fn), KEY,
                                     acfg=AsyncConfig.parity(M),
                                     eval_every=0, mesh=MESH)
        assert l_sync == l_async, (l_sync, l_async)
        for a, b in zip(jax.tree.leaves(p_sync), jax.tree.leaves(p_async)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        print(f"async sharded parity {strategy}: OK")

    # --- general path under the mesh: skewed trace, capacity 3 pads to 4
    # pool rows, partial staleness-bearing merges; admit scatter + merge
    # aggregation run sharded and keep training signal finite
    fl = _fl("fedfa")
    lat = lambda i: 30.0 if i % 3 == 2 else 1.0 + (i % 2)
    p, losses = run_async(PARAMS, CFG, fl, 4, TraceSource(data_fn, lat),
                          KEY, acfg=AsyncConfig(capacity=3, merge_k=2,
                                                staleness_max=3),
                          eval_every=0, mesh=MESH)
    assert len(losses) == 4 and all(np.isfinite(losses)), losses
    for leaf in jax.tree.leaves(p):
        assert np.isfinite(np.asarray(leaf)).all()
    print("async sharded general path: OK")

    # --- merge program collective structure: the bounded-staleness merge
    # aggregates the whole-row P("data") pool with ZERO all-gathers (the
    # invariant the slot-pool layout decision preserves)
    from repro.core.async_round import make_merge_program, merge_contract
    index = flat.get_index(PARAMS)
    rows = 4
    masks, gates, gmaps, _, _, _ = stack_runtimes(CFG, SPECS + SPECS[:1])
    g = jax.device_put(flat.flatten(index, PARAMS), csh.replicated(MESH))
    c = jax.device_put(jnp.zeros((rows, index.n), jnp.float32),
                       csh.cohort_sharding(MESH))
    w = jnp.asarray([5.0, 3.0, 0.0, 0.0], jnp.float32)
    fl_k = FLConfig(local_steps=E, lr=0.05, strategy="fedfa", task="cls",
                    agg_engine="flat", use_kernel=True, interpret=True)
    fn = make_merge_program(CFG, fl_k, index, mesh=MESH, rows=rows)
    txt = fn.lower(g, c, masks, gates, gmaps, w).compile().as_text()
    rep = merge_contract(index, MESH, rows=rows).check(hlo=txt)
    assert rep.ok, rep.violations
    assert rep.measured["peak_live_bytes_per_device"] > 0
    print("async merge collectives: all-gather=0 OK")

    # --- admit program collective structure: the slot-order select admits
    # with ZERO all-gathers (PR 8 killed the c_buf.at[slots].set scatter
    # whose runtime indices made GSPMD re-gather the full pool) and stays
    # inside the (2 + 5r)·N·4 peak budget
    from repro.core.async_round import admit_contract, make_admit_program
    from repro.core.server import default_class_masks
    _, batches_a = data_fn(0)
    (masks_a, gates_a, gmaps_a, _, cms_a, mal_a), bpad_a = csh.pad_cohort(
        stack_runtimes(CFG, SPECS), batches_a, rows - M)
    cms_in = default_class_masks(cms_a, CFG, fl_k, rows)
    keys_a = jax.random.split(KEY, rows)
    written = jnp.ones((rows,), jnp.int32)
    fn_a = make_admit_program(CFG, fl_k, index, any_malicious=False,
                              mesh=MESH, rows=rows)
    txt_a = fn_a.lower(g, c, masks_a, gates_a, gmaps_a, cms_in, mal_a,
                       bpad_a, keys_a, written).compile().as_text()
    rep_a = admit_contract(index, MESH, rows=rows).check(hlo=txt_a)
    assert rep_a.ok, rep_a.violations
    assert rep_a.measured["all_gathers"] == 0
    assert rep_a.measured["peak_live_bytes_per_device"] > 0
    print("async admit collectives: all-gather=0 OK")

    # --- all-overstale no-op regression (ISSUE 9): a merge whose ready
    # rows ALL exceed staleness_max must be a NO-OP — slots released,
    # deadline re-armed, g_buf bit-untouched (a divide-by-Σw on the empty
    # effective cohort would have 0/0-NaN'd the global)
    from repro.core.async_round import AsyncEngine
    eng = AsyncEngine(
        jax.device_put(flat.flatten(index, PARAMS),
                       csh.global_sharding(MESH)),
        CFG, fl_k, index, TraceSource(data_fn, lambda i: 1.0), KEY,
        acfg=AsyncConfig(capacity=3, merge_k=2, staleness_max=1),
        mesh=MESH)
    for _ in range(64):
        if eng.pool.ready(eng.now).any():
            break
        eng.step()
    ready = eng.pool.ready(eng.now)
    assert ready.any(), "fixture never produced a ready row"
    eng._materialize()
    g_host = np.asarray(jax.device_get(eng.g_buf))
    eng.version = int(eng.pool.version.max()) + eng.acfg.staleness_max + 1
    n_ready = int(ready.sum())
    assert eng._merge(ready) is None
    assert eng.dropped_rows == n_ready and eng.merges == 0
    assert not eng.pool.occupied[ready].any(), "over-stale slots not freed"
    assert eng.last_merge_t == eng.now, "deadline not re-armed"
    g_after = np.asarray(jax.device_get(eng.g_buf))
    np.testing.assert_array_equal(g_after, g_host)
    assert np.isfinite(g_after).all()
    print("all-overstale merge no-op: OK")

    # --- _cbufs regression: under the mesh, m=3 and m=4 cohorts both pad
    # to 4 rows and must ping-pong ONE scratch allocation (the old code
    # keyed on len(specs) and held a dead buffer per real size)
    driver = round_mod.ResidentDriver(CFG, fl, index, mesh=MESH)
    g_buf = jax.device_put(flat.flatten(index, PARAMS),
                           csh.global_sharding(MESH))
    _, batches3 = data_fn(0)
    g_buf, _ = driver.round(g_buf, SPECS, batches3, KEY)
    cbuf_first = driver._cbufs[(4, "f32")]
    specs4, data_fn4 = make_cohort(CFG, 4, local_steps=E)
    _, batches4 = data_fn4(0)
    g_buf, _ = driver.round(g_buf, specs4, batches4, KEY)
    assert len(driver._cbufs) == 1, \
        f"expected one scratch buffer for padded m=4, got {driver._cbufs.keys()}"
    assert cbuf_first.is_deleted(), \
        "m=4 cohort did not donate the m=3 cohort's padded scratch buffer"
    assert not driver._cbufs[(4, "f32")].is_deleted()
    print("cbufs padded-key ping-pong: OK")

    print("ASYNC OK")
    sys.exit(0)


if "--quant" in sys.argv:
    import dataclasses

    # --- quantized admission under the 4-device data mesh: the round
    # trains at f32, quantizes the admitted rows with per-segment scales,
    # and merges through the fused dequantize-accumulate; the merged
    # global must stay within quantization drift of the sharded f32 round
    # (error feedback keeps the bias from compounding)
    fl32 = _fl("fedfa")
    index = flat.get_index(PARAMS)
    p_f32, _ = round_mod.run_rounds(PARAMS, CFG, fl32, 2, data_fn, KEY,
                                    eval_every=0, mesh=MESH)
    for dt, tol in (("bf16", 0.02), ("int8", 0.08)):
        fl_q = dataclasses.replace(fl32, update_dtype=dt)
        p_q, l_q = round_mod.run_rounds(PARAMS, CFG, fl_q, 2, data_fn, KEY,
                                        eval_every=0, mesh=MESH)
        assert all(np.isfinite(l_q)), l_q
        num = den = 0.0
        for a, b in zip(jax.tree.leaves(p_f32), jax.tree.leaves(p_q)):
            num += float(np.sum((np.asarray(a) - np.asarray(b)) ** 2))
            den += float(np.sum(np.asarray(a) ** 2))
        drift = (num / max(den, 1e-30)) ** 0.5
        assert drift < tol, (dt, drift)
        print(f"quant sharded drift {dt}: {drift:.4f} OK")

    # --- _cbufs dtype-key regression: ONE driver serving f32 and int8
    # cohorts of the SAME padded size must hold one pool per admission
    # dtype — an (m,)-keyed dict would hand the f32 scratch to the int8
    # round (wrong dtype, wrong arity: the quantized state is a 4-tuple)
    driver = round_mod.ResidentDriver(CFG, fl32, index, mesh=MESH)
    g_buf = jax.device_put(flat.flatten(index, PARAMS),
                           csh.global_sharding(MESH))
    _, batches3 = data_fn(0)
    g_buf, _ = driver.round(g_buf, SPECS, batches3, KEY)
    cbuf_f32 = driver._cbufs[(4, "f32")]
    driver.fl = dataclasses.replace(fl32, update_dtype="int8")
    g_buf, _ = driver.round(g_buf, SPECS, batches3, KEY)
    assert set(driver._cbufs) == {(4, "f32"), (4, "int8")}, \
        f"expected dtype-keyed pools, got {driver._cbufs.keys()}"
    assert not cbuf_f32.is_deleted(), \
        "int8 round donated the f32 cohort scratch — dtype key collision"
    st = driver._cbufs[(4, "int8")]
    assert isinstance(st, tuple) and len(st) == 4, type(st)
    assert st[0].dtype == jax.numpy.int8 and st[2].dtype == jax.numpy.int8
    assert st[1].shape == (4, index.n_segments)
    # the int8 pool ping-pongs independently of the f32 scratch
    g_buf, _ = driver.round(g_buf, SPECS, batches3, KEY)
    assert all(b.is_deleted() for b in st), \
        "second int8 round did not donate the quantized 4-tuple state"
    assert not cbuf_f32.is_deleted()
    print("cbufs dtype-key ping-pong: OK")

    print("QUANT OK")
    sys.exit(0)


# --- parity: m=3 cohort padded to 4 shards must match the unsharded round
for strategy in ("fedfa", "heterofl"):
    fl = FLConfig(local_steps=E, lr=0.05, strategy=strategy, task="cls",
                  agg_engine="flat")
    p_un, l_un = round_mod.run_rounds(PARAMS, CFG, fl, 2, data_fn, KEY,
                                      eval_every=0)
    p_sh, l_sh = round_mod.run_rounds(PARAMS, CFG, fl, 2, data_fn, KEY,
                                      eval_every=0, mesh=MESH)
    np.testing.assert_allclose(l_un, l_sh, rtol=1e-4)
    assert_tree_allclose(p_un, p_sh)
    print(f"parity {strategy}: OK")

# --- donation still effective under NamedSharding (program cached above)
fl = FLConfig(local_steps=E, lr=0.05, strategy="fedfa", task="cls",
              agg_engine="flat")
index = flat.get_index(PARAMS)
runtimes = stack_runtimes(CFG, SPECS)
_, batches = data_fn(0)
g_buf = jax.device_put(flat.flatten(index, PARAMS), csh.replicated(MESH))
g2, c2, _ = round_mod.flat_round(g_buf, None, CFG, fl, index, runtimes,
                                 batches, KEY, mesh=MESH, any_malicious=True)
assert g_buf.is_deleted(), "donated global buffer not consumed"
assert c2.shape == (4, index.n), c2.shape          # padded to the 4 shards
assert c2.sharding.spec == jax.sharding.PartitionSpec("data")
g3, c3, _ = round_mod.flat_round(g2, c2, CFG, fl, index, runtimes, batches,
                                 KEY, mesh=MESH, any_malicious=True)
assert g2.is_deleted() and c2.is_deleted(), \
    "ping-pong donation broken under NamedSharding"
assert not (g3.is_deleted() or c3.is_deleted())
print("donation: OK")

print("MULTIDEVICE OK")
