import jax
import numpy as np
import pytest

# Tests see 1 CPU device (the dry-run's 512-device override is local to
# repro.launch.dryrun, never set here).
jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(autouse=True, scope="session")
def _clear_runtime_caches():
    """Drop core.server's cached device arrays when the session ends so
    arrays from a torn-down backend never leak into a later backend/mesh
    reconfiguration (the forced-device-count subprocess tests re-import in
    a fresh process, but in-process mesh tests share this one)."""
    yield
    from repro.core.server import clear_runtime_caches
    clear_runtime_caches()


def tiny(name: str, **over):
    """Reduced config for a registered arch with optional overrides."""
    from repro.configs import get_arch
    cfg = get_arch(name).reduced()
    return cfg.replace(**over) if over else cfg


_FL_FIXTURE = {}


def fl_round_fixture():
    """Shared (cfg, params) for the round-driver / sharded-round suites: the
    tiny 4-layer/2-section classification config and its init params, built
    once per process (init_params is the expensive part)."""
    if not _FL_FIXTURE:
        from repro.models import model as model_mod
        cfg = tiny("smollm-135m").replace(n_layers=4, n_sections=2,
                                          vocab_size=64, tie_embeddings=False)
        _FL_FIXTURE["cfg"] = cfg
        _FL_FIXTURE["params"] = model_mod.init_params(
            cfg, jax.random.PRNGKey(0))
    return _FL_FIXTURE["cfg"], _FL_FIXTURE["params"]


def make_cohort(cfg, m, *, n_classes=10, seq=8, batch=2, local_steps=2,
                malicious_frac=0.0, seed=0):
    """(specs, data_fn) for an m-client synthetic classification cohort —
    data_fn(r) returns (specs, stacked jnp batches) exactly like
    launch.train's per-round selection, deterministically in r."""
    import jax.numpy as jnp
    from repro.core.server import make_client_specs
    from repro.data import partition as part_mod
    from repro.data import pipeline, synthetic
    from repro.launch.train import client_arch_pool
    specs = make_client_specs(cfg, m, archs=client_arch_pool(cfg, "width"),
                              malicious_frac=malicious_frac, seed=seed)
    parts = part_mod.iid_partition(m, n_classes, seed=seed)
    profiles = synthetic.make_class_profiles(n_classes, cfg.vocab_size,
                                             seed=seed)

    def data_fn(r):
        b = pipeline.round_batches_cls(
            parts, list(range(m)), n_classes, cfg.vocab_size,
            local_steps=local_steps, batch=batch, seq_len=seq,
            profiles=profiles, seed=100 + r)
        return specs, {k: jnp.asarray(v) for k, v in b.items()}
    return specs, data_fn


def assert_tree_allclose(a, b, rtol=2e-4, atol=2e-5):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(x, np.float32),
                                   np.asarray(y, np.float32),
                                   rtol=rtol, atol=atol)


def make_batch(cfg, B=2, S=16, key=0):
    import jax.numpy as jnp
    k = jax.random.PRNGKey(key)
    batch = {"tokens": jax.random.randint(k, (B, S), 0, cfg.vocab_size)}
    if cfg.encoder is not None:
        batch["frames"] = 0.02 * jax.random.normal(
            jax.random.fold_in(k, 1), (B, cfg.encoder.n_frames, cfg.d_model))
    if cfg.vision is not None:
        batch["patches"] = 0.02 * jax.random.normal(
            jax.random.fold_in(k, 2), (B, cfg.vision.n_patches, cfg.vision.vit_dim))
    return batch
