import jax
import numpy as np
import pytest

# Tests see 1 CPU device (the dry-run's 512-device override is local to
# repro.launch.dryrun, never set here).
jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def tiny(name: str, **over):
    """Reduced config for a registered arch with optional overrides."""
    from repro.configs import get_arch
    cfg = get_arch(name).reduced()
    return cfg.replace(**over) if over else cfg


def make_batch(cfg, B=2, S=16, key=0):
    import jax.numpy as jnp
    k = jax.random.PRNGKey(key)
    batch = {"tokens": jax.random.randint(k, (B, S), 0, cfg.vocab_size)}
    if cfg.encoder is not None:
        batch["frames"] = 0.02 * jax.random.normal(
            jax.random.fold_in(k, 1), (B, cfg.encoder.n_frames, cfg.d_model))
    if cfg.vision is not None:
        batch["patches"] = 0.02 * jax.random.normal(
            jax.random.fold_in(k, 2), (B, cfg.vision.n_patches, cfg.vision.vit_dim))
    return batch
