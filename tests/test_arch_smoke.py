"""Per-assigned-architecture smoke tests: a REDUCED variant of the same
family (<=2 layers, d_model<=512, <=4 experts) runs one forward and one
train step on CPU; output shapes + no NaNs asserted."""
import jax
import jax.numpy as jnp
import pytest

from conftest import make_batch, tiny

from repro.configs import ASSIGNED, get_arch
from repro.launch.steps import make_train_step
from repro.models import model as model_mod
from repro.optim import init_opt

ARCH_IDS = list(ASSIGNED)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_forward(arch):
    cfg = tiny(arch)
    assert cfg.n_layers <= 2 * len(cfg.pattern_unit)
    assert cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.n_experts <= 4
    params = model_mod.init_params(cfg, jax.random.PRNGKey(0))
    batch = make_batch(cfg)
    logits, aux = model_mod.forward(params, cfg, batch, remat=False)
    B, S = batch["tokens"].shape
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert not bool(jnp.isnan(logits).any())


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_train_step(arch):
    cfg = tiny(arch)
    params = model_mod.init_params(cfg, jax.random.PRNGKey(0))
    opt = init_opt(params, cfg.optimizer)
    step = jax.jit(make_train_step(cfg, total_steps=10))
    batch = make_batch(cfg, B=2, S=16)
    # step=1: schedules with warmup (wsd) have lr=0 at step 0
    new_params, new_opt, loss = step(params, opt, batch, jnp.ones((), jnp.int32))
    assert jnp.isfinite(loss)
    # parameters actually moved
    delta = max(float(jnp.abs(a - b).max())
                for a, b in zip(jax.tree.leaves(new_params),
                                jax.tree.leaves(params)))
    assert delta > 0
    assert not any(bool(jnp.isnan(x).any()) for x in jax.tree.leaves(new_params))


@pytest.mark.parametrize("arch", ["smollm-135m", "mamba2-130m",
                                  "recurrentgemma-2b", "whisper-base",
                                  "internvl2-76b", "phi3.5-moe-42b-a6.6b"])
def test_reduced_decode_matches_forward(arch):
    """Prefill + decode == full forward (teacher forcing), per family."""
    cfg = tiny(arch)
    if cfg.moe:
        import dataclasses
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, capacity_factor=100.0))
    params = model_mod.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 12
    batch = make_batch(cfg, B=B, S=S)
    full_logits, _ = model_mod.forward(params, cfg, batch, remat=False)
    pre = dict(batch)
    pre["tokens"] = batch["tokens"][:, :S - 3]
    cap = S + 4 + (cfg.vision.n_patches if cfg.vision else 0)
    lg, caches, enc = model_mod.prefill(params, cfg, pre, capacity=cap,
                                        cache_dtype=jnp.float32)
    errs = [float(jnp.abs(lg[:, 0] - full_logits[:, S - 4]).max())]
    for i in range(S - 3, S):
        lg, caches = model_mod.decode_step(params, cfg,
                                           batch["tokens"][:, i:i + 1],
                                           caches, enc_out=enc)
        errs.append(float(jnp.abs(lg[:, 0] - full_logits[:, i]).max()))
    assert max(errs) < 5e-4, errs
