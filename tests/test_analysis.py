"""Tests for the ``repro.analysis`` program-contract subsystem: the HLO
collective parser, the jaxpr visitor (fused-quantile read/sort pins), the
Contract/Report machinery, the runtime passes (donation, cache keys,
``_cbufs`` hygiene), the FL source lints (planted fixture must flag,
``src/`` must be clean), and the ``masks.py`` ValueError regressions."""
from pathlib import Path

import pytest

from repro.analysis import Contract, contracts, hlo, jaxpr as jaxpr_mod
from repro.analysis import lint, passes

REPO = Path(__file__).resolve().parent.parent
FIXTURE = Path(__file__).resolve().parent / "fixtures" / \
    "lint_bad_traced_split.py"


# ---------------------------------------------------------------------------
# hlo: structured collective parsing
# ---------------------------------------------------------------------------

# Representative lines: CPU sync form, TPU async -start/-done pairs, a
# tuple-shaped async all-reduce (payload + u32[] sync flag), a
# layout-annotated tuple all-gather (operand, result), and an op name
# inside metadata that must NOT count.
HLO_SAMPLE = """\
HloModule jit_round, input_output_alias={ {0}: (0, {}, may-alias), {1}: (1, {}, must-alias) }

  %ar0 = f32[1024]{0} all-reduce(f32[1024]{0} %x), replica_groups={{0,1,2,3}}
  %ag0 = (f32[256]{0:T(256)}, f32[1024]{0:T(256)}) all-gather-start(f32[256]{0} %y), replica_groups=[2,2]<=[4]
  %ag0d = f32[1024]{0} all-gather-done((f32[256]{0}, f32[1024]{0}) %ag0)
  %ar1 = (f32[512]{0}, u32[]) all-reduce-start(f32[512]{0} %z)
  %ar1d = f32[512]{0} all-reduce-done((f32[512]{0}, u32[]) %ar1)
  %rs0 = f32[128]{0} reduce-scatter(f32[512]{0} %w), replica_groups={{0,1,2,3}}
  %f = f32[8]{0} fusion(f32[8]{0} %a), metadata={op_name="all-gather-fusion"}
"""


def test_hlo_collectives_counts_and_async_pairs():
    ops = hlo.collectives(HLO_SAMPLE, strict=True)
    assert hlo.count(ops, "all-reduce") == 2
    assert hlo.count(ops, "all-gather") == 1
    assert hlo.count(ops, "reduce-scatter") == 1
    assert hlo.count(ops, "all-to-all") == 0
    # the metadata op_name and the -done halves never count
    assert len(ops) == 4


def test_hlo_tuple_payload_is_float_max_not_first_shape():
    ops = hlo.collectives(HLO_SAMPLE)
    ag = next(op for op in ops if op.kind == "all-gather")
    assert ag.is_async and ag.elems == 1024      # result, not the operand
    ar1 = next(op for op in ops if op.kind == "all-reduce" and op.is_async)
    assert ar1.elems == 512                      # payload, not the u32[] flag


def test_hlo_sizes_max_and_replica_groups():
    assert hlo.sizes(HLO_SAMPLE, "all-reduce") == [1024, 512]
    assert hlo.sizes(HLO_SAMPLE, "all-reduce", min_elems=600) == [1024]
    assert hlo.max_elems(HLO_SAMPLE, "all-gather") == 1024
    assert hlo.summarize(HLO_SAMPLE) == {
        "all-reduce": 2, "all-gather": 1, "reduce-scatter": 1}
    groups = [op.replica_groups for op in hlo.collectives(HLO_SAMPLE)]
    assert "{{0,1,2,3}}" in groups and "[2,2]<=[4]" in groups


def test_hlo_strict_raises_on_unbalanced_pairs():
    trunc = HLO_SAMPLE.replace(
        "%ar1d = f32[512]{0} all-reduce-done((f32[512]{0}, u32[]) %ar1)", "")
    hlo.collectives(trunc)                       # lenient: fine
    with pytest.raises(ValueError, match="unbalanced"):
        hlo.collectives(trunc, strict=True)


def test_hlo_result_elems_on_tuple_and_layout_lines():
    assert hlo.result_elems(
        "%a = (f32[512]{0}, u32[]) all-reduce-start(f32[512]{0} %z)") == 512
    assert hlo.result_elems("%a = f32[16,8]{1,0:T(256)} add(...)") == 128
    assert hlo.result_elems("ROOT %t = () tuple()") is None


def test_hlo_donated_params_parses_module_header():
    donated = hlo.donated_params(HLO_SAMPLE)
    assert donated == {0: "may-alias", 1: "must-alias"}
    assert hlo.donated_params("HloModule plain\n") == {}


def test_hlo_byte_totals():
    totals = hlo.byte_totals(
        "%ar = f32[100]{0} all-reduce(f32[100]{0} %x)\n"
        "%cp = bf16[10]{0} collective-permute(bf16[10]{0} %y)\n")
    assert totals == {"all-reduce": 400, "collective-permute": 20,
                      "total": 420}


# ---------------------------------------------------------------------------
# jaxpr visitor: the fused-quantile structural pin
# ---------------------------------------------------------------------------

def _quantile_fns():
    import jax
    import jax.numpy as jnp
    from repro.core import flat

    rows = jax.random.normal(jax.random.PRNGKey(0), (4, 8, 512),
                             jnp.float32)
    q = jnp.full((4,), 1.0 - 0.05 * 0.5, jnp.float32)

    def topk(rows, q):
        ra = jnp.abs(rows)
        t = flat._row_quantile(ra, q, 0.95)
        return jnp.sqrt(flat._rows_trimmed_sq(ra, t))

    def fused(rows, q):
        _, sq = flat._rows_trimmed_stats(rows, q, 0.95, True, True)
        return jnp.sqrt(sq)

    return rows, q, topk, fused


def test_jaxpr_walk_pins_fused_and_topk_counts():
    rows, q, topk, fused = _quantile_fns()
    c_fused = jaxpr_mod.trace_counts(fused, rows, q, row_elems=rows.size)
    c_topk = jaxpr_mod.trace_counts(topk, rows, q, row_elems=rows.size)
    assert (c_fused.reads, c_fused.sorts) == (1, 0)
    assert (c_topk.reads, c_topk.sorts) == (7, 1)


def test_quantile_contracts_hold_on_traced_jaxprs():
    import jax
    from repro.kernels.fedfa_quantile.ops import (fused_quantile_contract,
                                                  topk_tail_contract)
    rows, q, topk, fused = _quantile_fns()
    rep_f = fused_quantile_contract().check(
        jaxpr=jax.make_jaxpr(fused)(rows, q), row_elems=rows.size)
    rep_t = topk_tail_contract().check(
        jaxpr=jax.make_jaxpr(topk)(rows, q), row_elems=rows.size)
    assert rep_f.ok, rep_f.violations
    assert rep_t.ok, rep_t.violations


def test_quantile_contract_fails_on_oracle_path():
    """ISSUE 9 satellite 1 (non-vacuity): a lowered program that took the
    jnp-oracle path — as the old ``_MAX_ROW_ELEMS`` fallback silently did
    for long rows even under ``use_kernel=True`` — must FAIL the fused and
    multilevel contracts, not pass them vacuously: the oracle's lowering
    sorts and re-reads the rows."""
    import jax
    import jax.numpy as jnp
    from repro.kernels.fedfa_quantile import ops as qops
    from repro.kernels.fedfa_quantile.multilevel import \
        multilevel_quantile_contract
    from repro.kernels.fedfa_quantile.ops import fused_quantile_contract

    rows = jax.random.normal(jax.random.PRNGKey(0), (2, 2048), jnp.float32)
    q = jnp.full((2,), 0.975, jnp.float32)
    oracle = jax.make_jaxpr(
        lambda r, qq: qops.row_trimmed_stats(r, qq, use_kernel=False,
                                             interpret=False))(rows, q)
    rep_f = fused_quantile_contract().check(jaxpr=oracle,
                                            row_elems=rows.size)
    rep_m = multilevel_quantile_contract().check(jaxpr=oracle,
                                                 row_elems=rows.size)
    assert not rep_f.ok and not rep_m.ok
    joined = " ".join(rep_f.violations)
    assert "sorts" in joined or "row_reads" in joined


# ---------------------------------------------------------------------------
# contracts: bounds, validation, evaluation
# ---------------------------------------------------------------------------

def test_check_bound_forms():
    assert contracts.check_bound("x", 3, 3) is None
    assert "expected exactly 2" in contracts.check_bound("x", 3, 2)
    assert contracts.check_bound("x", 3, (1, None)) is None
    assert "expected >= 4" in contracts.check_bound("x", 3, (4, None))
    assert "expected <= 2" in contracts.check_bound("x", 3, (None, 2))
    assert contracts.check_bound("x", 3, None) is None


def test_contract_requires_payload_sizes():
    with pytest.raises(ValueError, match="cohort_elems"):
        Contract(name="bad", full_cohort_gathers=0)
    with pytest.raises(ValueError, match="scale_elems"):
        Contract(name="bad", scale_allreduces=1)


def test_contract_check_against_hlo_text():
    c = Contract(name="t", all_gathers=1, reduce_scatters=(1, None),
                 allreduce_max_elems=2048, scale_allreduces=(1, 2),
                 scale_elems=512, full_cohort_gathers=0, cohort_elems=4096,
                 donated=frozenset({0, 1}))
    rep = c.check(hlo=HLO_SAMPLE)
    assert rep.ok, rep.violations
    assert rep.measured["scale_allreduces"] == 1
    assert rep.measured["donated"] == [0, 1]

    tight = Contract(name="t2", all_gathers=0, allreduce_max_elems=600,
                     donated=frozenset({2}))
    rep2 = tight.check(hlo=HLO_SAMPLE)
    assert not rep2.ok
    joined = " ".join(rep2.violations)
    assert "all_gathers" in joined and "exceed" in joined \
        and "donation" in joined


def test_contract_missing_inputs_is_a_violation():
    rep = Contract(name="t", all_gathers=0).check()
    assert not rep.ok and "no compiled HLO" in rep.violations[0]
    rep = Contract(name="t", row_reads=1).check()
    assert not rep.ok and "no jaxpr" in rep.violations[0]


def test_format_table_marks_failures():
    good = Contract(name="g", all_gathers=1).check(hlo=HLO_SAMPLE)
    bad = Contract(name="b", all_gathers=0).check(hlo=HLO_SAMPLE)
    table = contracts.format_table([good, bad])
    assert "PASS" in table and "FAIL b:" in table


# ---------------------------------------------------------------------------
# passes: donation, cache keys, auditor, _cbufs
# ---------------------------------------------------------------------------

def test_check_donation_reports_missing_alias():
    assert passes.check_donation(HLO_SAMPLE, [0, 1]) == []
    msgs = passes.check_donation(HLO_SAMPLE, [0, 3])
    assert len(msgs) == 1 and "parameter 3" in msgs[0]


def test_check_cache_keys_flags_collisions():
    assert passes.check_cache_keys([("a", (1,)), ("b", (2,))]) == []
    msgs = passes.check_cache_keys(
        [("mesh=None", (1, "x")), ("mesh=2x2", (1, "x")),
         ("mesh=None", (1, "x"))])          # same-label repeat is fine
    assert len(msgs) == 1 and "collision" in msgs[0]


def test_recompile_auditor_records_and_restores():
    from collections import OrderedDict
    from repro.core import round as round_mod

    with passes.RecompileAuditor() as aud:
        round_mod._ROUND_CACHE["_analysis_probe"] = "p"
        assert round_mod._ROUND_CACHE.get("_analysis_probe") == "p"
        round_mod._ROUND_CACHE.get("_analysis_missing")
    try:
        assert aud.inserts == 1 and aud.hits == 1
        assert aud.report() == {"hits": 1, "inserts": 1, "evictions": 0}
        # plain OrderedDict restored: no recording after exit
        assert type(round_mod._ROUND_CACHE) is OrderedDict
        round_mod._ROUND_CACHE.get("_analysis_probe")
        assert aud.hits == 1
    finally:
        round_mod._ROUND_CACHE.pop("_analysis_probe", None)


def test_audit_cbufs_flags_bad_keys_and_dead_buffers():
    class FakeBuf:
        def __init__(self, rows, deleted=False):
            self.shape = (rows, 16)
            self._deleted = deleted

        def is_deleted(self):
            return self._deleted

    class FakeDriver:
        pass

    d = FakeDriver()
    d._cbufs = {4: FakeBuf(4)}
    assert passes.audit_cbufs(d) == []
    d._cbufs = {3: FakeBuf(4), 4: FakeBuf(4, deleted=True)}
    msgs = passes.audit_cbufs(d)
    assert len(msgs) == 2
    assert any("key does not match" in m for m in msgs)
    assert any("deleted buffer" in m for m in msgs)


def test_round_key_variants_do_not_collide():
    """The PR 5/6 bug class, as a key property: every variant that must
    compile a distinct program gets a distinct ``_round_key``."""
    from repro.core.round import _round_key
    from repro.core.server import FLConfig
    from repro.launch.mesh import make_data_mesh
    from conftest import tiny

    cfg = tiny("smollm-135m")
    fl = FLConfig(local_steps=1, lr=0.05, strategy="fedfa", task="cls",
                  agg_engine="flat")
    mesh = make_data_mesh()
    keyed = [
        ("no mesh", _round_key(cfg, fl, None, any_malicious=False)),
        ("data mesh", _round_key(cfg, fl, None, any_malicious=False,
                                 mesh=mesh)),
        ("padded m=3", _round_key(cfg, fl, None, any_malicious=False,
                                  mesh=mesh, m_real=3)),
        ("malicious", _round_key(cfg, fl, None, any_malicious=True,
                                 mesh=mesh)),
        ("no donate", _round_key(cfg, fl, None, any_malicious=False,
                                 mesh=mesh, donate=False)),
    ]
    assert passes.check_cache_keys(keyed) == []
    # a rebuilt-identical mesh maps to the SAME key (no spurious retrace)
    mesh2 = make_data_mesh()
    assert _round_key(cfg, fl, None, any_malicious=False, mesh=mesh) \
        == _round_key(cfg, fl, None, any_malicious=False, mesh=mesh2)


# ---------------------------------------------------------------------------
# lint: planted fixture flags, src/ is clean
# ---------------------------------------------------------------------------

def test_lint_flags_planted_fixture():
    findings = lint.lint_paths([str(FIXTURE)])
    rules = {f.rule for f in findings}
    assert rules == {"traced-random-split", "bare-assert", "import-time-jnp"}
    split = next(f for f in findings if f.rule == "traced-random-split")
    assert "bad_round_step" in split.message
    assert str(FIXTURE) in str(split) and f":{split.line}:" in str(split)


def test_lint_src_tree_is_clean():
    """The tier-1 shim for ``python -m repro.analysis lint src/``."""
    findings = lint.lint_paths([str(REPO / "src")])
    assert findings == [], "\n".join(str(f) for f in findings)


def test_lint_noqa_suppression_and_syntax_error():
    src = "import jax.numpy as jnp\nx = jnp.zeros((2,))  # noqa: import-time-jnp\n"
    assert lint.lint_source(src, "a.py") == []
    src2 = "import jax.numpy as jnp\nx = jnp.zeros((2,))  # noqa: bare-assert\n"
    assert [f.rule for f in lint.lint_source(src2, "a.py")] \
        == ["import-time-jnp"]
    bad = lint.lint_source("def f(:\n", "b.py")
    assert [f.rule for f in bad] == ["syntax-error"]


def test_lint_kernels_exempt_from_bare_assert():
    src = "def f(x):\n    assert x.ndim == 2\n    return x\n"
    assert lint.lint_source(src, "src/repro/kernels/foo/kernel.py") == []
    assert [f.rule for f in lint.lint_source(src, "src/repro/core/foo.py")] \
        == ["bare-assert"]


# ---------------------------------------------------------------------------
# masks.py: ValueError regressions (formerly bare asserts)
# ---------------------------------------------------------------------------

def test_width_spec_rejects_bad_multiplier_with_value():
    from repro.models.masks import width_spec
    from conftest import tiny
    cfg = tiny("smollm-135m")
    for w in (0.0, -0.5, 1.5):
        with pytest.raises(ValueError, match=repr(w)):
            width_spec(cfg, w)


def test_depth_gates_reject_bad_section_depths_with_value():
    from repro.models.masks import depth_gates, max_section_depths
    from conftest import tiny
    cfg = tiny("smollm-135m").replace(n_layers=4, n_sections=2)
    full = max_section_depths(cfg)
    with pytest.raises(ValueError, match="section depths"):
        depth_gates(cfg, full + (1,))
    with pytest.raises(ValueError, match="depth 0 invalid"):
        depth_gates(cfg, (0,) + full[1:])
    with pytest.raises(ValueError, match=f"depth {full[0] + 1} invalid"):
        depth_gates(cfg, (full[0] + 1,) + full[1:])


# ---------------------------------------------------------------------------
# hlo: metadata / provenance parsing + parser edge cases
# ---------------------------------------------------------------------------

def test_hlo_parse_metadata_fields():
    line = ('  %ag = f32[64]{0} all-gather(f32[16]{0} %x), '
            'metadata={op_name="jit(round)/jit(main)/scatter" '
            'source_file="/a/b/async_round.py" source_line=191}')
    md = hlo.parse_metadata(line)
    assert md == {"op_name": "jit(round)/jit(main)/scatter",
                  "source_file": "/a/b/async_round.py",
                  "source_line": 191}
    assert hlo.parse_metadata("%a = f32[8]{0} add(%x, %y)") == {}


def test_hlo_collectives_carry_provenance():
    txt = ('  %ag = f32[64]{0} all-gather(f32[16]{0} %x), '
           'metadata={op_name="jit(f)/gather" '
           'source_file="/p/q/round.py" source_line=42}\n'
           '  %ar = f32[8]{0} all-reduce(f32[8]{0} %y)\n')
    ops = hlo.collectives(txt)
    ag = next(op for op in ops if op.kind == "all-gather")
    assert (ag.op_name, ag.source_file, ag.source_line) \
        == ("jit(f)/gather", "/p/q/round.py", 42)
    ar = next(op for op in ops if op.kind == "all-reduce")
    assert ar.op_name is None and ar.source_line is None


def test_hlo_multi_operand_fusion_and_mixed_dtype_tuples():
    # a tuple-result async start mixing bf16 payload and u32 flag: the
    # payload is the max over FLOAT shapes, and bytes respect the dtype
    txt = ('  %s = (bf16[256]{0}, u32[4]{0}) all-reduce-start'
           '(bf16[256]{0} %x)\n'
           '  %d = bf16[256]{0} all-reduce-done'
           '((bf16[256]{0}, u32[4]{0}) %s)\n')
    ops = hlo.collectives(txt, strict=True)
    assert len(ops) == 1 and ops[0].elems == 256
    assert hlo.byte_totals(txt)["all-reduce"] == 256 * 2 + 4 * 4
    # multi-operand fusion result shapes parse (nested tuple + layouts)
    line = ('%f = (f32[8,4]{1,0:T(256)}, u32[2]{0}) fusion'
            '(f32[8,4]{1,0} %a, f32[4]{0} %b, u32[2]{0} %c), kind=kLoop')
    assert hlo.result_elems(line) == 32


def test_hlo_donation_aliases_on_tuple_outputs():
    hdr = ("HloModule m, input_output_alias={ {0}: (0, {}, must-alias), "
           "{2}: (3, {}, may-alias) }\n")
    assert hlo.donated_params(hdr) == {0: "must-alias", 3: "may-alias"}
    from repro.analysis import memory
    assert memory._output_aliases(hdr) == {0: 0, 2: 3}


# ---------------------------------------------------------------------------
# memory: the static liveness analyzer
# ---------------------------------------------------------------------------

from repro.analysis import blame, memory  # noqa: E402

# f32[100] p0 (400 B) + f32[50] p1 (200 B) params; an 800 B concatenate
# live until the slice consumes it; the 400 B ROOT element 0 is donated
# back onto p0 (collapsed); a 100 B slice survives to the output.
MEM_SAMPLE = """\
HloModule jit_f, is_scheduled=true, input_output_alias={ {0}: (0, {}, must-alias) }

ENTRY %main (p0: f32[100], p1: f32[50]) -> (f32[100], f32[25]) {
  %p0 = f32[100]{0} parameter(0)
  %p1 = f32[50]{0} parameter(1)
  %big = f32[200]{0} concatenate(f32[100]{0} %p0, f32[50]{0} %p1), dimensions={0}
  %a = f32[100]{0} add(f32[100]{0} %p0, f32[100]{0} %p0)
  %s = f32[25]{0} slice(f32[200]{0} %big), slice={[0:25]}
  ROOT %t = (f32[100]{0}, f32[25]{0}) tuple(f32[100]{0} %a, f32[25]{0} %s)
}
"""


def test_memory_liveness_peak_and_donation_collapse():
    est = memory.analyze(MEM_SAMPLE)
    # at the slice: params (600) + big (800, freed after) + s (100); the
    # donated %a is collapsed to zero
    assert est.peak_bytes == 1500
    assert est.param_bytes == 600
    assert est.donated_collapsed == 400
    assert est.output_bytes == 100          # only the fresh slice
    names = [n for n, _ in est.top]
    assert "big" in names
    # without the donation header the 400 B add stays allocated
    undonated = MEM_SAMPLE.replace(
        ", input_output_alias={ {0}: (0, {}, must-alias) }", "")
    est2 = memory.analyze(undonated)
    assert est2.peak_bytes == 1900
    assert est2.donated_collapsed == 0
    assert memory.peak_live_bytes(undonated) == 1900


def test_memory_view_ops_are_free_and_params_live_throughout():
    txt = """\
HloModule m, is_scheduled=true

ENTRY %e (p0: f32[100]) -> f32[100] {
  %p0 = f32[100]{0} parameter(0)
  %t = (f32[100]{0}) tuple(f32[100]{0} %p0)
  %g = f32[100]{0} get-tuple-element((f32[100]{0}) %t), index=0
  %b = f32[100]{0} bitcast(f32[100]{0} %g)
  ROOT %o = f32[100]{0} optimization-barrier(f32[100]{0} %b)
}
"""
    est = memory.analyze(txt)
    assert est.peak_bytes == 400            # just the parameter
    assert est.output_bytes == 0            # output aliases the input view


def test_memory_while_subcomputation_transient():
    txt = """\
HloModule m, is_scheduled=true

%body.2 (pb: (f32[100], s32[])) -> (f32[100], s32[]) {
  %pb = (f32[100]{0}, s32[]) parameter(0)
  %gb = f32[100]{0} get-tuple-element((f32[100]{0}, s32[]) %pb), index=0
  %tmp = f32[100]{0} multiply(f32[100]{0} %gb, f32[100]{0} %gb)
  %ib = s32[] get-tuple-element((f32[100]{0}, s32[]) %pb), index=1
  ROOT %rb = (f32[100]{0}, s32[]) tuple(f32[100]{0} %tmp, s32[] %ib)
}

%cond.3 (pc: (f32[100], s32[])) -> pred[] {
  %pc = (f32[100]{0}, s32[]) parameter(0)
  %ic = s32[] get-tuple-element((f32[100]{0}, s32[]) %pc), index=1
  %c5 = s32[] constant(5)
  ROOT %lt = pred[] compare(s32[] %ic, s32[] %c5), direction=LT
}

ENTRY %main (p0: f32[100]) -> f32[100] {
  %p0 = f32[100]{0} parameter(0)
  %z = s32[] constant(0)
  %init = (f32[100]{0}, s32[]) tuple(f32[100]{0} %p0, s32[] %z)
  %w = (f32[100]{0}, s32[]) while((f32[100]{0}, s32[]) %init), condition=%cond.3, body=%body.2
  ROOT %out = f32[100]{0} get-tuple-element((f32[100]{0}, s32[]) %w), index=0
}
"""
    est = memory.analyze(txt)
    # params 400 + constant 4 + the body's 400 B %tmp transient at the
    # while; the while itself allocates nothing (in-place carry)
    assert est.peak_bytes == 804
    body = memory.split_computations(txt)[0]["body.2"]
    assert [i.op for i in body][0] == "parameter"


def test_memory_requires_entry():
    with pytest.raises(ValueError, match="ENTRY"):
        memory.analyze("HloModule m\n")


def test_memory_peak_contract_bound_fails_with_top_buffers():
    c = Contract(name="m", peak_live_bytes_per_device=(None, 1000))
    rep = c.check(hlo=MEM_SAMPLE)
    assert not rep.ok
    v = rep.violations[0]
    assert "peak_live_bytes_per_device" in v and "1500" in v
    assert "largest live buffers" in v
    ok = Contract(name="m2", peak_live_bytes_per_device=(None, 2000)) \
        .check(hlo=MEM_SAMPLE)
    assert ok.ok and ok.measured["peak_live_bytes_per_device"] == 1500


# ---------------------------------------------------------------------------
# blame: collective-to-source attribution
# ---------------------------------------------------------------------------

BLAME_SAMPLE = (
    '  %ag0 = f32[1024]{0} all-gather(f32[256]{0} %x), '
    'metadata={op_name="jit(admit)/jit(main)/scatter" '
    'source_file="/repo/src/repro/core/async_round.py" source_line=191}\n'
    '  %ag1 = f32[1024]{0} all-gather(f32[256]{0} %y), '
    'metadata={op_name="jit(admit)/jit(main)/scatter" '
    'source_file="/repo/src/repro/core/async_round.py" source_line=191}\n'
    '  %ar0 = f32[64]{0} all-reduce(f32[64]{0} %z), '
    'metadata={op_name="jit(round)/add" '
    'source_file="/repo/src/repro/core/flat.py" source_line=190}\n'
    '  %cp = f32[8]{0} collective-permute(f32[8]{0} %w)\n')


def test_blame_table_groups_by_source_line():
    rows = blame.blame_table(BLAME_SAMPLE)
    assert rows[0].kind == "all-gather"
    assert rows[0].source == "async_round.py:191"
    assert rows[0].count == 2 and rows[0].total_elems == 2048
    assert rows[0].op_name == "scatter"
    unattributed = next(r for r in rows if r.kind == "collective-permute")
    assert unattributed.source is None


def test_blame_describe_and_format():
    ops = hlo.collectives(BLAME_SAMPLE)
    d = blame.describe(ops[0])
    assert d == "all-gather[1024] scatter (async_round.py:191)"
    d2 = blame.describe(next(o for o in ops
                             if o.kind == "collective-permute"))
    assert "(no provenance)" in d2
    lines = blame.format_blame(BLAME_SAMPLE, kinds=["all-gather"])
    assert len(lines) == 1 and "x2" in lines[0] \
        and "async_round.py:191" in lines[0]


def test_contract_violation_names_blamed_source_line():
    c = Contract(name="t", all_gathers=0)
    rep = c.check(hlo=BLAME_SAMPLE)
    assert not rep.ok
    assert "async_round.py:191" in rep.violations[0]
    assert rep.blame and rep.blame[0].source == "async_round.py:191"


def test_report_to_json_roundtrips():
    import json
    rep = Contract(name="t", all_gathers=(None, 4)).check(hlo=BLAME_SAMPLE)
    d = json.loads(json.dumps(rep.to_json()))
    assert d["program"] == "t" and d["ok"]
    assert d["measured"]["all_gathers"] == 2
    assert any(b["source"] == "async_round.py:191" for b in d["blame"])


# ---------------------------------------------------------------------------
# lint: host-sync-in-program rule
# ---------------------------------------------------------------------------

HOST_SYNC_FIXTURE = Path(__file__).resolve().parent / "fixtures" / \
    "lint_bad_host_sync.py"


def test_lint_flags_host_sync_fixture():
    findings = lint.lint_paths([str(HOST_SYNC_FIXTURE)])
    assert [f.rule for f in findings] == ["host-sync-in-program"] * 3
    lines = sorted(f.line for f in findings)
    assert len(set(lines)) == 3            # float(), .item(), np.asarray
    assert all("_round" in f.message for f in findings)


def test_lint_host_sync_scope_rules():
    # un-jitted helpers may convert freely; methods sharing a jitted
    # closure's NAME must not be flagged (the PR 8 scope-resolution fix)
    src = (
        "import jax\n"
        "import numpy as np\n"
        "def make(fn):\n"
        "    def _merge(x):\n"
        "        return x * 2\n"
        "    return jax.jit(_merge)\n"
        "class Engine:\n"
        "    def _merge(self, x):\n"
        "        return float(np.asarray(x).sum())\n")
    assert lint.lint_source(src, "a.py") == []
    bad = src.replace("return x * 2", "return float(x.sum())")
    assert [f.rule for f in lint.lint_source(bad, "a.py")] \
        == ["host-sync-in-program"]
    suppressed = src.replace(
        "return x * 2", "return float(x.sum())  # noqa: host-sync-in-program")
    assert lint.lint_source(suppressed, "a.py") == []


# ---------------------------------------------------------------------------
# sharding: the collectives shim is gone
# ---------------------------------------------------------------------------

def test_sharding_collectives_shim_removed():
    """PR 8 deleted the ``repro.sharding.collectives`` back-compat shim;
    the one copy of the HLO parsing rules is ``repro.analysis.hlo``."""
    with pytest.raises(ImportError):
        import repro.sharding.collectives  # noqa: F401
