"""Flat-buffer aggregation engine: flatten/unflatten round-trip and
kernel-vs-reference parity against the tree engine for every strategy
preset over a heterogeneous cohort."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tiny

from repro.core import fedfa, flat
from repro.models import model as model_mod
from repro.models.masks import ClientArch, full_client, stack_masks

CFG = tiny("smollm-135m").replace(n_layers=4, n_sections=2)


def _cohort(cfg, archs, *, poison_last=False, seed=0):
    """Stacked runtimes for a cohort: per-client perturbed copies of the
    global model (the last client optionally a malicious +10 outlier)."""
    g = model_mod.init_params(cfg, jax.random.PRNGKey(seed))
    ks = jax.random.split(jax.random.PRNGKey(seed + 1), len(archs))
    clients = [jax.tree.map(
        lambda x, kk=k: x + 0.05 * jax.random.normal(kk, x.shape, jnp.float32)
        .astype(x.dtype), g) for k in ks]
    if poison_last:
        clients[-1] = jax.tree.map(lambda x: x + 10.0, clients[-1])
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *clients)
    masks = stack_masks([a.masks(cfg) for a in archs])
    gates = jnp.stack([a.gates(cfg) for a in archs])
    gmaps = jnp.stack([a.graft(cfg) for a in archs])
    nd = jnp.asarray(np.arange(1, len(archs) + 1), jnp.float32)
    return g, stacked, masks, gates, gmaps, nd


def _assert_tree_allclose(a, b, rtol=1e-4, atol=1e-5):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(x, np.float32),
                                   np.asarray(y, np.float32),
                                   rtol=rtol, atol=atol)


# Heterogeneous cohort: mixed widths 0.25/0.5/1.0, mixed section depths,
# and a malicious full-width full-depth client.
HETERO = [ClientArch(0.25, (1, 1)), ClientArch(0.5, (2, 1)),
          ClientArch(1.0, (1, 2)), full_client(CFG)]


@pytest.mark.parametrize("strategy", sorted(fedfa.STRATEGIES))
def test_flat_matches_tree_all_strategies(strategy):
    g, stacked, masks, gates, gmaps, nd = _cohort(
        CFG, HETERO, poison_last=True)
    kw = fedfa.STRATEGIES[strategy]
    out_tree = fedfa.aggregate(g, stacked, CFG, masks, gates, gmaps, nd,
                               engine="tree", **kw)
    out_flat = fedfa.aggregate(g, stacked, CFG, masks, gates, gmaps, nd,
                               engine="flat", **kw)
    _assert_tree_allclose(out_tree, out_flat)


def test_flat_matches_tree_under_jit():
    g, stacked, masks, gates, gmaps, nd = _cohort(CFG, HETERO)

    @jax.jit
    def both(g, s, mk, gt, gm, nd):
        t = fedfa.aggregate(g, s, CFG, mk, gt, gm, nd, engine="tree")
        f = fedfa.aggregate(g, s, CFG, mk, gt, gm, nd, engine="flat")
        return t, f
    out_tree, out_flat = both(g, stacked, masks, gates, gmaps, nd)
    _assert_tree_allclose(out_tree, out_flat)


def test_flat_keeps_global_where_no_client_updates():
    """γ = 0 case: with every client at width 0.25, channels outside the
    0.25 prefix receive no update and must keep the previous global value
    (and never become NaN)."""
    archs = [ClientArch(0.25, (1, 1))] * 3
    g, stacked, masks, gates, gmaps, nd = _cohort(CFG, archs)
    out = fedfa.aggregate(g, stacked, CFG, masks, gates, gmaps, nd,
                          engine="flat", graft=True, scale=True)
    assert not any(bool(jnp.isnan(x).any()) for x in jax.tree.leaves(out))
    # a fully-masked slice: the top d_ff channels of stage-0 ffn w_gate
    w_new = out["stages"][0][0]["ffn"]["w_gate"]
    w_old = g["stages"][0][0]["ffn"]["w_gate"]
    np.testing.assert_array_equal(np.asarray(w_new[..., -1]),
                                  np.asarray(w_old[..., -1]))
    # parity holds in the γ=0 regime too
    out_tree = fedfa.aggregate(g, stacked, CFG, masks, gates, gmaps, nd,
                               engine="tree", graft=True, scale=True)
    _assert_tree_allclose(out_tree, out)


def test_flat_gamma_zero_cohort_keeps_global_exactly():
    """Depth-gated partial aggregation: stage-0 rows no client holds keep
    the previous global value bit-for-bit."""
    archs = [ClientArch(1.0, (1, 1))] * 2      # depth slots 1 and 3 empty
    g, stacked, masks, gates, gmaps, nd = _cohort(CFG, archs)
    out = fedfa.aggregate(g, stacked, CFG, masks, gates, gmaps, nd,
                          engine="flat", graft=False, scale=False)
    wq = out["stages"][0][0]["attn"]["wq"]
    np.testing.assert_array_equal(np.asarray(wq[1]),
                                  np.asarray(g["stages"][0][0]["attn"]["wq"][1]))


def test_flatten_unflatten_roundtrip():
    g = model_mod.init_params(CFG, jax.random.PRNGKey(3))
    index = flat.get_index(g)
    buf = flat.flatten(index, g)
    assert buf.shape == (index.n,) and buf.dtype == jnp.float32
    back = flat.unflatten(index, buf)
    assert jax.tree_util.tree_structure(back) == jax.tree_util.tree_structure(g)
    for a, b in zip(jax.tree.leaves(g), jax.tree.leaves(back)):
        assert a.shape == b.shape and a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_flatten_rejects_mismatched_tree():
    g = model_mod.init_params(CFG, jax.random.PRNGKey(3))
    index = flat.get_index(g)
    with pytest.raises(ValueError, match="does not match FlatIndex"):
        flat.flatten(index, {"embed": g["embed"]})


def test_flat_index_segments_consistent():
    g = model_mod.init_params(CFG, jax.random.PRNGKey(3))
    index = flat.get_index(g)
    assert index.row_of.shape == (index.n,)
    assert index.row_of.max() == index.n_segments - 1
    # segment ids are contiguous leaf-major runs
    assert (np.diff(index.row_of) >= 0).all()
    # graft metadata: identity off stage 0
    off_stage0 = index.g_rest == 0
    idx = np.arange(index.n)
    assert (index.g_base[off_stage0] == idx[off_stage0]).all()
    # stage-0 leaves exist in this config and carry row/rest info
    assert (~off_stage0).any() and index.seg_stage0.any()


def test_flat_graft_matches_tree_graft():
    g = model_mod.init_params(CFG, jax.random.PRNGKey(4))
    index = flat.get_index(g)
    gmap = ClientArch(1.0, (1, 2)).graft(CFG)
    grafted_tree = fedfa.graft_stage0(g, gmap)
    grafted_flat = flat.unflatten(
        index, flat._graft_flat(index, flat.flatten(index, g), gmap))
    _assert_tree_allclose(grafted_tree, grafted_flat, rtol=0, atol=0)


def test_flat_engine_interpret_mode_matches_tree():
    """Full engine through the Pallas kernels in interpret mode (the TPU
    code path, executed on CPU) against the tree engine."""
    cfg = tiny("smollm-135m")          # smallest: interpret mode is slow
    archs = [ClientArch(0.5, (1,) * cfg.n_sections), full_client(cfg)]
    g, stacked, masks, gates, gmaps, nd = _cohort(cfg, archs)
    out_tree = fedfa.aggregate(g, stacked, cfg, masks, gates, gmaps, nd,
                               engine="tree", graft=True, scale=True)
    out_flat = fedfa.aggregate(g, stacked, cfg, masks, gates, gmaps, nd,
                               engine="flat", graft=True, scale=True,
                               use_kernel=True, interpret=True)
    _assert_tree_allclose(out_tree, out_flat)


def _random_mixed_tree(rng: np.random.Generator, depth=0):
    """Random nested dict/tuple/list pytree with mixed bf16/f32 leaves."""
    def leaf():
        shape = tuple(int(rng.integers(1, 5))
                      for _ in range(int(rng.integers(0, 3))))
        dtype = jnp.bfloat16 if rng.random() < 0.5 else jnp.float32
        return jnp.asarray(rng.standard_normal(shape), jnp.float32) \
            .astype(dtype)
    if depth >= 2:
        return leaf()
    kids = [_random_mixed_tree(rng, depth + 1)
            for _ in range(int(rng.integers(1, 4)))]
    kind = rng.integers(3)
    if kind == 0:
        return {f"k{i}": c for i, c in enumerate(kids)}
    return tuple(kids) if kind == 1 else list(kids)


@pytest.mark.parametrize("seed", range(8))
def test_roundtrip_mixed_dtypes_property(seed):
    """Property: flatten -> unflatten over arbitrary mixed-dtype pytrees is
    the identity — exact dtype restoration (bf16 embeds losslessly in the f32
    buffer) and exact structure."""
    tree = _random_mixed_tree(np.random.default_rng(seed))
    index = flat.get_index(tree)
    back = flat.unflatten(index, flat.flatten(index, tree))
    assert jax.tree_util.tree_structure(back) == \
        jax.tree_util.tree_structure(tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        assert b.dtype == a.dtype and b.shape == a.shape
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_index_cache_distinguishes_treedefs():
    """Two pytrees with identical (path, shape, dtype) flatten order but
    different structure (tuple vs list share SequenceKey paths) must get
    distinct FlatIndexes — the old cache key collided here and unflatten
    returned the wrong container type."""
    x = jnp.ones((3,), jnp.float32)
    idx_tuple = flat.get_index({"a": (x,)})
    idx_list = flat.get_index({"a": [x]})
    assert idx_tuple is not idx_list
    assert idx_tuple.treedef != idx_list.treedef
    back = flat.unflatten(idx_list, flat.flatten(idx_list, {"a": [x]}))
    assert isinstance(back["a"], list)
    back_t = flat.unflatten(idx_tuple, flat.flatten(idx_tuple, {"a": (x,)}))
    assert isinstance(back_t["a"], tuple)


def test_index_cache_bounded():
    """The index cache is LRU-bounded instead of growing without limit."""
    for i in range(flat._INDEX_CACHE_MAX + 8):
        flat.get_index({f"leaf{i}": jnp.zeros((i + 1,), jnp.float32)})
    assert len(flat._INDEX_CACHE) <= flat._INDEX_CACHE_MAX
    # most-recent entries survive (LRU evicts from the front)
    i = flat._INDEX_CACHE_MAX + 7
    probe = {f"leaf{i}": jnp.zeros((i + 1,), jnp.float32)}
    before = len(flat._INDEX_CACHE)
    flat.get_index(probe)
    assert len(flat._INDEX_CACHE) == before


def test_single_client_cohort():
    """m=1: mean norm equals the client's own norm, α=1, aggregate returns
    the (masked, grafted) client update where γ>0."""
    archs = [full_client(CFG)]
    g, stacked, masks, gates, gmaps, nd = _cohort(CFG, archs)
    out_tree = fedfa.aggregate(g, stacked, CFG, masks, gates, gmaps, nd,
                               engine="tree", graft=True, scale=True)
    out_flat = fedfa.aggregate(g, stacked, CFG, masks, gates, gmaps, nd,
                               engine="flat", graft=True, scale=True)
    _assert_tree_allclose(out_tree, out_flat)
    client = jax.tree.map(lambda x: x[0], stacked)
    _assert_tree_allclose(client, out_flat, rtol=1e-4, atol=1e-4)
