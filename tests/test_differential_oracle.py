"""Tree engine as the differential oracle for the flat production engine.

The tree engine (``fedfa.aggregate(engine="tree")``) is no longer on any
hot path — its job is to be an independently-implemented Alg. 1 that the
flat engine is diffed against over randomized heterogeneous cohorts: all 7
strategy presets x random width/depth mixes x malicious flags x random
(possibly zero) data counts.  Randomization is hypothesis-driven when
hypothesis is installed and falls back to a fixed seeded sweep otherwise.

The suite carries the ``oracle`` marker so quick runs can deselect it
(``pytest -m "not oracle"``); it runs by default in tier-1.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import fl_round_fixture

from repro.core import fedfa, flat
from repro.models.masks import ClientArch, stack_masks

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

pytestmark = pytest.mark.oracle

CFG, PARAMS = fl_round_fixture()
_WIDTHS = (0.25, 0.5, 0.75, 1.0)
SEEDS = range(5)


@functools.lru_cache(maxsize=16)
def _random_cohort(seed: int):
    """Random hetero cohort: m in [1, 5] clients with random widths, random
    per-section depths, random malicious (+10 outlier) flags and random data
    counts including n_data = 0 clients."""
    rng = np.random.default_rng(seed)
    bounds = CFG.section_bounds()
    m = int(rng.integers(1, 6))
    archs = [ClientArch(float(rng.choice(_WIDTHS)),
                        tuple(int(rng.integers(1, hi - lo + 1))
                              for lo, hi in bounds))
             for _ in range(m)]
    malicious = rng.random(m) < 0.3
    nd = rng.integers(0, 5, m).astype(np.float32)
    if nd.sum() == 0:
        nd[int(rng.integers(m))] = 3.0

    ks = jax.random.split(jax.random.PRNGKey(seed + 1), m)
    clients = []
    for i, k in enumerate(ks):
        c = jax.tree.map(
            lambda x, kk=k: x + 0.05 * jax.random.normal(
                kk, x.shape, jnp.float32).astype(x.dtype), PARAMS)
        if malicious[i]:
            c = jax.tree.map(lambda x: x + 10.0, c)
        clients.append(c)
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *clients)
    masks = stack_masks([a.masks(CFG) for a in archs])
    gates = jnp.stack([a.gates(CFG) for a in archs])
    gmaps = jnp.stack([a.graft(CFG) for a in archs])
    return stacked, masks, gates, gmaps, jnp.asarray(nd)


def _check_parity(seed: int, strategy: str, rtol=1e-4, atol=1e-5):
    stacked, masks, gates, gmaps, nd = _random_cohort(seed)
    kw = fedfa.STRATEGIES[strategy]
    out_tree = fedfa.aggregate(PARAMS, stacked, CFG, masks, gates, gmaps,
                               nd, engine="tree", **kw)
    out_flat = fedfa.aggregate(PARAMS, stacked, CFG, masks, gates, gmaps,
                               nd, engine="flat", **kw)
    for x, y in zip(jax.tree.leaves(out_tree), jax.tree.leaves(out_flat)):
        np.testing.assert_allclose(np.asarray(x, np.float32),
                                   np.asarray(y, np.float32),
                                   rtol=rtol, atol=atol)


@pytest.mark.parametrize("strategy", sorted(fedfa.STRATEGIES))
@pytest.mark.parametrize("seed", SEEDS)
def test_flat_matches_tree_oracle(seed, strategy):
    """Flat == tree on random hetero cohorts for every strategy preset."""
    _check_parity(seed, strategy)


if HAVE_HYPOTHESIS:
    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=2**31 - 1),
           strategy=st.sampled_from(sorted(fedfa.STRATEGIES)))
    def test_flat_matches_tree_oracle_hypothesis(seed, strategy):
        """Hypothesis-driven sweep over the same cohort space."""
        _check_parity(seed, strategy)


def test_async_merges_match_tree_oracle():
    """Every async merge (parity fast path AND general bounded-staleness
    path, malicious straggler included) re-aggregated by the tree engine
    from the engine's own host snapshot — slot rows, staleness-discounted
    weights, per-row specs — must reproduce the merged global."""
    from conftest import assert_tree_allclose, make_cohort

    from repro.core.async_round import AsyncConfig, run_async
    from repro.core.server import FLConfig, stack_runtimes
    from repro.sim import ParitySource, TraceSource

    fl = FLConfig(local_steps=2, lr=0.05, strategy="fedfa", task="cls",
                  agg_engine="flat")
    index = flat.get_index(PARAMS)
    specs, data_fn = make_cohort(CFG, 4, local_steps=2, malicious_frac=0.3)
    key = jax.random.PRNGKey(3)
    rec = []
    # skewed trace -> partial, staleness-bearing merges (general path)
    run_async(PARAMS, CFG, fl, 3,
              TraceSource(data_fn, lambda i: 20.0 if i % 4 == 3 else 1.0),
              key, acfg=AsyncConfig(capacity=4, merge_k=2, staleness_max=3),
              eval_every=0, on_merge=rec.append)
    # full-cohort trace -> parity fast path merges
    run_async(PARAMS, CFG, fl, 2, ParitySource(data_fn), key,
              acfg=AsyncConfig.parity(4), eval_every=0, on_merge=rec.append)
    assert len(rec) == 5
    kw = fedfa.STRATEGIES[fl.strategy]
    saw_pregrafted = False
    for info in rec:
        g_before = flat.unflatten(index, jnp.asarray(info["g_before"]))
        rows = [flat.unflatten(index, jnp.asarray(r)) for r in info["x"]]
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *rows)
        masks, gates, gmaps, _, _, _ = stack_runtimes(CFG, info["specs"])
        if info["pregrafted"]:
            # general-path rows were grafted at admission — an identity
            # graft map keeps graft-on weighting without permuting again
            gmaps = jnp.broadcast_to(jnp.arange(gmaps.shape[1]), gmaps.shape)
            saw_pregrafted = True
        out_tree = fedfa.aggregate(g_before, stacked, CFG, masks, gates,
                                   gmaps, jnp.asarray(info["w"]),
                                   engine="tree", **kw)
        assert_tree_allclose(out_tree,
                             flat.unflatten(index, jnp.asarray(info["g_after"])))
    assert saw_pregrafted  # the general bounded-staleness path was exercised


def _rel_drift(a, b):
    """Relative L2 distance between two pytrees/arrays (oracle in ``a``)."""
    num = den = 0.0
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        num += float(np.sum((np.asarray(x, np.float32) -
                             np.asarray(y, np.float32)) ** 2))
        den += float(np.sum(np.asarray(x, np.float32) ** 2))
    return (num / max(den, 1e-30)) ** 0.5


@pytest.mark.parametrize("dt,bound", [("bf16", 0.03), ("int8", 0.08)])
@pytest.mark.parametrize("seed", range(3))
def test_quantized_aggregation_drift_vs_tree_oracle(seed, dt, bound):
    """Quantized admission (grafted, density-masked rows quantized with
    per-segment scales, fused dequantize in every consumer) stays within
    quantization drift of the f32 tree oracle on randomized heterogeneous
    cohorts — malicious +10 outliers included (``_random_cohort`` flags
    ~30% of clients)."""
    stacked, masks, gates, gmaps, nd = _random_cohort(seed)
    index = flat.get_index(PARAMS)
    g = flat.flatten(index, PARAMS)
    x = flat.flatten_stacked(index, stacked)
    x = jax.vmap(functools.partial(flat._graft_flat, index))(x, gmaps)
    dens, _ = jax.vmap(
        functools.partial(flat._density_and_fraction, CFG, index))(masks)
    y = x * dens                              # what _round_q admits
    x_q, scales = flat.quantize_cohort(index, y, dt)
    out_q = flat.aggregate_buffers(
        index, g, x_q, CFG, masks, gates, gmaps, nd, pregrafted=True,
        scales=scales, use_kernel=True, interpret=True,
        **fedfa.STRATEGIES["fedfa"])
    # oracle: the tree engine on the same pre-grafted f32 rows (identity
    # graft maps keep graft-on weighting without permuting again)
    rows = [flat.unflatten(index, y[i]) for i in range(y.shape[0])]
    stacked_g = jax.tree.map(lambda *xs: jnp.stack(xs), *rows)
    gmaps_id = jnp.broadcast_to(jnp.arange(gmaps.shape[1]), gmaps.shape)
    out_tree = fedfa.aggregate(PARAMS, stacked_g, CFG, masks, gates,
                               gmaps_id, nd, engine="tree",
                               **fedfa.STRATEGIES["fedfa"])
    drift = _rel_drift(out_tree, flat.unflatten(index, out_q))
    assert drift < bound, (dt, seed, drift)


@pytest.mark.parametrize("dt,bound", [("bf16", 0.02), ("int8", 0.08)])
def test_quantized_error_feedback_converges(dt, bound):
    """Multi-round sweep: with server-side error feedback the quantized
    resident trajectory stays within epsilon of the f32 trajectory after 6
    rounds — the per-round quantization residual must not compound."""
    import dataclasses

    from conftest import make_cohort
    from repro.core import round as round_mod
    from repro.core.server import FLConfig

    fl = FLConfig(local_steps=2, lr=0.05, strategy="fedfa", task="cls",
                  agg_engine="flat")
    _, data_fn = make_cohort(CFG, 3, local_steps=2, malicious_frac=0.34)
    key = jax.random.PRNGKey(9)
    p_f32, l_f32 = round_mod.run_rounds(PARAMS, CFG, fl, 6, data_fn, key,
                                        eval_every=0)
    fl_q = dataclasses.replace(fl, update_dtype=dt)
    p_q, l_q = round_mod.run_rounds(PARAMS, CFG, fl_q, 6, data_fn, key,
                                    eval_every=0)
    assert np.isfinite(l_q).all(), l_q
    drift = _rel_drift(p_f32, p_q)
    assert drift < bound, (dt, drift)


def test_quantized_async_merges_match_tree_oracle():
    """Async quantized admission: every bounded-staleness merge,
    re-aggregated by the TREE engine from the engine's own dequantized
    pool snapshot, must reproduce the merged global — the fused
    dequantize-accumulate and the explicit dequantize agree merge by
    merge (the density 0/1 mask is baked into the stored rows, so the
    oracle's re-application is idempotent)."""
    import dataclasses

    from conftest import assert_tree_allclose, make_cohort
    from repro.core.async_round import AsyncConfig, run_async
    from repro.core.server import FLConfig, stack_runtimes
    from repro.sim import TraceSource

    fl = FLConfig(local_steps=2, lr=0.05, strategy="fedfa", task="cls",
                  agg_engine="flat", update_dtype="int8")
    index = flat.get_index(PARAMS)
    _, data_fn = make_cohort(CFG, 4, local_steps=2, malicious_frac=0.3)
    rec = []
    run_async(PARAMS, CFG, fl, 3,
              TraceSource(data_fn, lambda i: 20.0 if i % 4 == 3 else 1.0),
              jax.random.PRNGKey(3),
              acfg=AsyncConfig(capacity=4, merge_k=2, staleness_max=3),
              eval_every=0, on_merge=rec.append)
    assert rec, "skewed trace produced no merges"
    kw = fedfa.STRATEGIES[fl.strategy]
    for info in rec:
        assert info["pregrafted"]
        g_before = flat.unflatten(index, jnp.asarray(info["g_before"]))
        rows = [flat.unflatten(index, jnp.asarray(r)) for r in info["x"]]
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *rows)
        masks, gates, gmaps, _, _, _ = stack_runtimes(CFG, info["specs"])
        gmaps = jnp.broadcast_to(jnp.arange(gmaps.shape[1]), gmaps.shape)
        out_tree = fedfa.aggregate(g_before, stacked, CFG, masks, gates,
                                   gmaps, jnp.asarray(info["w"]),
                                   engine="tree", **kw)
        assert_tree_allclose(
            out_tree, flat.unflatten(index, jnp.asarray(info["g_after"])),
            rtol=5e-4, atol=5e-5)


def test_backdoor_robustness_row_int8():
    """Table-1-style robustness row at int8 admission: the clean-vs-
    attacked accuracy drop under the lambda=20 label-shuffle attack must
    survive quantization — int8's drop tracks f32's and the attacked int8
    run keeps a usable global (quantized admission must not hand the
    attacker a new amplification channel)."""
    from repro.launch.train import run_fl

    accs = {}
    for dt in ("f32", "int8"):
        for attack, frac in (("clean", 0.0), ("attacked", 0.4)):
            h = run_fl("smollm-135m", 4, 5, strategy="fedfa",
                       malicious_frac=frac, attack_lambda=20.0,
                       local_steps=1, batch=2, seq_len=8,
                       participation=1.0, eval_every=0, seed=0,
                       update_dtype=dt, quiet=True)
            assert np.isfinite(h["loss"]).all(), (dt, attack, h["loss"])
            accs[(dt, attack)] = h["final_acc"]
    drop_f32 = accs[("f32", "clean")] - accs[("f32", "attacked")]
    drop_int8 = accs[("int8", "clean")] - accs[("int8", "attacked")]
    assert abs(drop_int8 - drop_f32) <= 0.25, (drop_f32, drop_int8, accs)
    assert accs[("int8", "attacked")] >= accs[("f32", "attacked")] - 0.25, \
        accs


@pytest.mark.parametrize("seed", range(3))
def test_kernelized_cohort_norms_match_reference(seed):
    """The fused Pallas trimmed-norm pass (use_kernel=True, interpret=True:
    the TPU code path on CPU) is bit-tolerant-equal (<= 1e-5 rel) to the
    jnp reference path on differential-oracle cohorts."""
    stacked, masks, _, _, _ = _random_cohort(seed)
    index = flat.get_index(PARAMS)
    dens, fracs = jax.vmap(
        functools.partial(flat._density_and_fraction, CFG, index))(masks)
    xm = flat.flatten_stacked(index, stacked) * dens
    ref = flat._cohort_norms(index, xm, fracs, 0.95, False, False)
    ker = flat._cohort_norms(index, xm, fracs, 0.95, True, True)
    np.testing.assert_allclose(np.asarray(ker), np.asarray(ref),
                               rtol=1e-5, atol=1e-7)
