"""Resident-buffer multi-round driver (repro.core.round): parity with the
per-round path, buffer donation, and one-compile-per-cohort-shape."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import assert_tree_allclose as _assert_tree_allclose
from conftest import fl_round_fixture, make_cohort

from repro.core import flat
from repro.core import round as round_mod
from repro.core.server import FLConfig, fl_round, fl_round_flat, \
    stack_runtimes

CFG, PARAMS = fl_round_fixture()
E, M = 2, 3
KEY = jax.random.PRNGKey(0)


def _fl(strategy):
    return FLConfig(local_steps=E, lr=0.05, strategy=strategy, task="cls",
                    agg_engine="flat")


@pytest.fixture(scope="module")
def cohort():
    return make_cohort(CFG, M, local_steps=E)


@pytest.mark.parametrize("strategy", ["fedfa", "heterofl"])
def test_resident_matches_sequential_fl_rounds(cohort, strategy):
    """R resident rounds == R per-round fl_round dispatches (same cohort,
    same per-round keys) within float tolerance."""
    specs, data_fn = cohort
    fl = _fl(strategy)
    R = 3
    p_res, losses = round_mod.run_rounds(PARAMS, CFG, fl, R, data_fn, KEY,
                                         eval_every=2)
    assert len(losses) == R

    p = PARAMS
    for r in range(R):
        p, loss = fl_round(p, CFG, fl, specs, data_fn(r)[1],
                           jax.random.fold_in(KEY, r))
        np.testing.assert_allclose(losses[r], float(loss), rtol=1e-5)
    _assert_tree_allclose(p, p_res)


def test_round_donates_both_buffers(cohort):
    """The jitted round consumes its donated inputs: the previous (N,) global
    and (m, N) cohort buffers are deleted after the call, and the returned
    cohort buffer can be donated back on the next round."""
    specs, data_fn = cohort
    fl = _fl("fedfa")
    index = flat.get_index(PARAMS)
    runtimes = stack_runtimes(CFG, specs)
    _, batches = data_fn(0)

    g_buf = flat.flatten(index, PARAMS)
    c_buf = jnp.zeros((M, index.n), jnp.float32)
    g2, c2, loss = round_mod.flat_round(
        g_buf, c_buf, CFG, fl, index, runtimes, batches, KEY)
    assert g_buf.is_deleted() and c_buf.is_deleted()
    assert g2.shape == (index.n,) and c2.shape == (M, index.n)

    g3, c3, _ = round_mod.flat_round(
        g2, c2, CFG, fl, index, runtimes, batches, KEY)
    assert g2.is_deleted() and c2.is_deleted()
    assert not (g3.is_deleted() or c3.is_deleted())


def test_round_compiles_once_per_cohort_shape(cohort):
    """Same cohort shape -> one executable; make_flat_round returns the
    cached program and jit adds exactly one cache entry."""
    specs, data_fn = cohort
    fl = _fl("fedfa")
    index = flat.get_index(PARAMS)
    fn = round_mod.make_flat_round(CFG, fl, index, any_malicious=False)
    assert round_mod.make_flat_round(CFG, fl, index, any_malicious=False) is fn
    if not hasattr(fn, "_cache_size"):    # private jax API; skip, don't break
        pytest.skip("jitted-fn _cache_size unavailable in this jax")

    driver = round_mod.ResidentDriver(CFG, fl, index)
    g_buf = flat.flatten(index, PARAMS)
    for r in range(3):
        g_buf, _ = driver.round(g_buf, specs, data_fn(r)[1],
                                jax.random.fold_in(KEY, r))
    assert fn._cache_size() == 1          # 3 rounds, same shape: 1 executable

    # a different cohort shape compiles exactly one more program
    _, b0 = data_fn(0)
    g_buf, _ = driver.round(g_buf, specs[:2],
                            {k: v[:2] for k, v in b0.items()},
                            jax.random.fold_in(KEY, 99))
    assert fn._cache_size() == 2


def test_fl_round_flat_matches_fl_round(cohort):
    """The server-level flat entry point shares stack_runtimes and matches
    the tree-in/tree-out round."""
    specs, data_fn = cohort
    fl = _fl("fedfa")
    index = flat.get_index(PARAMS)
    _, batches = data_fn(0)

    p_tree, loss_tree = fl_round(PARAMS, CFG, fl, specs, batches, KEY)
    g_buf = flat.flatten(index, PARAMS)
    g2, _, loss_flat = fl_round_flat(g_buf, CFG, fl, specs, batches, KEY,
                                     index=index)
    np.testing.assert_allclose(float(loss_tree), float(loss_flat), rtol=1e-6)
    _assert_tree_allclose(p_tree, flat.unflatten(index, g2))

    with pytest.raises(ValueError, match="FlatIndex"):
        fl_round_flat(g2, CFG, fl, specs, batches, KEY)


def test_checkpoint_from_resident_buffer(cohort, tmp_path):
    """save_from_buffer at an eval boundary == save of the unflattened tree;
    restore_to_buffer round-trips back onto the resident representation."""
    from repro.checkpoint import checkpoint as ckpt_mod
    index = flat.get_index(PARAMS)
    g_buf = flat.flatten(index, PARAMS)
    path = str(tmp_path / "resident")
    ckpt_mod.save_from_buffer(path, index, g_buf, meta={"round": 7})
    tree, meta = ckpt_mod.restore(path, PARAMS)
    assert meta["round"] == 7 and meta["flat_n"] == index.n
    _assert_tree_allclose(tree, PARAMS, rtol=0, atol=0)

    idx2, buf2, meta2 = ckpt_mod.restore_to_buffer(path, PARAMS)
    assert idx2 is index                      # same layout -> cached index
    np.testing.assert_array_equal(np.asarray(buf2), np.asarray(g_buf))


def test_run_rounds_eval_and_ckpt_boundaries(cohort, tmp_path):
    """eval_fn fires at eval_every boundaries + final round; checkpoints are
    written from the resident buffer at the same rounds."""
    import os
    specs, data_fn = cohort
    fl = _fl("heterofl")
    seen = []
    p, losses = round_mod.run_rounds(
        PARAMS, CFG, fl, 4, data_fn, KEY, eval_every=2,
        eval_fn=lambda r, loss, tree: seen.append(r),
        ckpt_path=str(tmp_path / "ck"))
    assert seen == [0, 2, 3]
    for r in seen:
        assert os.path.exists(tmp_path / f"ck_r{r:05d}.npz")
