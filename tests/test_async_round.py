"""Async engine: parity bit-equality, staleness policy, slot-pool state.

The correctness anchor is ``test_parity_bit_equal``: the async engine in
parity mode (full-pool merges, staleness 0, deterministic full-cohort
arrivals) must be BIT-equal to ``run_rounds`` — same losses, same params —
because every parity merge dispatches the literal resident-round program.
The staleness tests pin the bounded-influence guarantee: an over-stale
malicious update is dropped (weight exactly 0), a within-bound stale one
is discounted below its fresh weight.
"""
import jax
import numpy as np
import pytest

from conftest import fl_round_fixture, make_cohort

from repro.core import flat
from repro.core.async_round import (AsyncConfig, AsyncEngine, SlotPool,
                                    run_async, staleness_weight)
from repro.core.round import run_rounds
from repro.core.server import FLConfig
from repro.sim import ParitySource, TraceSource

CFG, PARAMS = fl_round_fixture()
M, E = 4, 2
KEY = jax.random.PRNGKey(7)


def _fl(strategy):
    return FLConfig(local_steps=E, lr=0.05, strategy=strategy, task="cls",
                    agg_engine="flat")


@pytest.mark.parametrize("strategy", ["fedfa", "heterofl"])
def test_parity_bit_equal(strategy):
    """Parity mode (staleness 0, full cohort, deterministic trace) is
    BIT-equal to run_rounds — losses and final params — including a
    malicious cohort."""
    specs, data_fn = make_cohort(CFG, M, local_steps=E, malicious_frac=0.3)
    assert any(s.malicious for s in specs)
    fl = _fl(strategy)
    p_sync, l_sync = run_rounds(PARAMS, CFG, fl, 3, data_fn, KEY,
                                eval_every=0)
    p_async, l_async = run_async(PARAMS, CFG, fl, 3, ParitySource(data_fn),
                                 KEY, acfg=AsyncConfig.parity(M),
                                 eval_every=0)
    assert l_sync == l_async          # host floats, exact
    for a, b in zip(jax.tree.leaves(p_sync), jax.tree.leaves(p_async)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_staleness_weight():
    acfg = AsyncConfig(capacity=4, merge_k=2, staleness_max=3)
    w = staleness_weight(np.arange(6), acfg)
    assert w[0] == 1.0                              # fresh: full weight
    assert np.all(np.diff(w[:4]) < 0)               # strictly decaying
    np.testing.assert_allclose(w[:4], 1.0 / np.sqrt(1.0 + np.arange(4)),
                               rtol=1e-6)
    np.testing.assert_array_equal(w[4:], 0.0)       # beyond the bound: zero
    const = AsyncConfig(capacity=4, merge_k=2, staleness_max=3,
                        discount="const")
    np.testing.assert_array_equal(staleness_weight(np.arange(6), const),
                                  [1, 1, 1, 1, 0, 0])


def test_async_config_validation():
    with pytest.raises(ValueError):
        AsyncConfig(capacity=0)
    with pytest.raises(ValueError):
        AsyncConfig(capacity=4, merge_k=5)
    with pytest.raises(ValueError):
        AsyncConfig(capacity=4, merge_k=0)
    with pytest.raises(ValueError):
        AsyncConfig(discount="linear")


def test_slot_pool_state():
    pool = SlotPool(capacity=3, rows=4)             # 1 mesh pad row
    assert list(pool.free_slots()) == [0, 1, 2]     # pad row never free-listed
    from repro.core.server import ClientSpec
    from repro.models.masks import full_client
    spec = ClientSpec(arch=full_client(CFG), n_data=10)
    pool.admit(np.asarray([0, 2]), [spec, spec], np.asarray([1.0, 5.0]),
               now=0.0, version=0)
    assert list(pool.free_slots()) == [1]
    np.testing.assert_array_equal(pool.ready(1.0), [True, False, False, False])
    np.testing.assert_array_equal(pool.ready(5.0), [True, False, True, False])
    pool.release(pool.ready(1.0))
    assert list(pool.free_slots()) == [0, 1]
    assert pool.nd[0] == 0.0 and pool.specs[0] is None


def _straggler_engine(staleness_max, merges, rec):
    """capacity-2 engine over a stream whose malicious client is a
    straggler: it arrives ~8 sim-seconds in, by which time ~7 fast merges
    bumped the version, so its staleness far exceeds a small bound."""
    specs, data_fn = make_cohort(CFG, M, local_steps=E, malicious_frac=0.3,
                                 seed=1)
    mal = [i for i, s in enumerate(specs) if s.malicious]
    assert mal, "cohort must include an attacker"
    lat = lambda i: 8.0 if specs[i % M].malicious else 1.0
    fl = _fl("fedfa")
    index = flat.get_index(PARAMS)
    g_buf = flat.flatten(index, PARAMS)
    eng = AsyncEngine(
        g_buf, CFG, fl, index, TraceSource(data_fn, lat), KEY,
        acfg=AsyncConfig(capacity=2, merge_k=1,
                         staleness_max=staleness_max),
        on_merge=rec.append)
    while eng.merges < merges:
        eng.step()
    return eng


def test_stale_malicious_influence_bounded():
    """Over-stale malicious updates are DROPPED (weight exactly 0, never
    merged); within-bound stale ones merge with a discounted weight
    strictly below their fresh n_data weight."""
    rec = []
    eng = _straggler_engine(staleness_max=1, merges=10, rec=rec)
    # the straggler arrived over-stale at least once and was dropped
    assert eng.dropped_rows >= 1
    for info in rec:                  # ... and NEVER merged with weight > 0
        for i, s in enumerate(info["specs"]):
            if s.malicious:
                assert info["w"][i] == 0.0

    # generous bound: the straggler now merges, but discounted
    rec2 = []
    eng2 = _straggler_engine(staleness_max=1000, merges=10, rec=rec2)
    mal_ws = [(info["w"][i], float(s.n_data))
              for info in rec2 for i, s in enumerate(info["specs"])
              if s.malicious and info["w"][i] > 0]
    assert mal_ws, "straggler never merged under the generous bound"
    for w, nd in mal_ws:
        assert 0.0 < w < nd           # discounted strictly below fresh
    assert eng2.dropped_rows == 0     # nothing exceeds the generous bound


def test_skewed_trace_progresses():
    """Partial bounded-staleness merges on a skewed trace still train:
    per-merge losses stay finite and the engine's simulated clock moves at
    the fast clients' cadence, not the straggler's."""
    specs, data_fn = make_cohort(CFG, M, local_steps=E)
    lat = lambda i: 40.0 if i % M == M - 1 else 1.0 + (i % 3)
    rec = []
    p, losses = run_async(PARAMS, CFG, _fl("fedfa"), 5,
                          TraceSource(data_fn, lat), KEY,
                          acfg=AsyncConfig(capacity=4, merge_k=2,
                                           staleness_max=3),
                          eval_every=0, on_merge=rec.append)
    assert len(losses) == 5 and all(np.isfinite(losses))
    assert len(rec) == 5
    # every merge consumed >= merge_k rows' worth of weight
    assert all(np.count_nonzero(info["w"]) >= 1 for info in rec)
    for leaf in jax.tree.leaves(p):
        assert np.isfinite(np.asarray(leaf)).all()


def test_run_async_noop():
    p, losses = run_async(PARAMS, CFG, _fl("fedfa"), 0,
                          ParitySource(lambda r: ([], {})), KEY,
                          acfg=AsyncConfig.parity(1))
    assert losses == [] and p is PARAMS


def test_population_traces_deterministic():
    """The hashed client population replays bit-for-bit and stays cheap at
    millions of registered clients (no per-client state)."""
    from repro.sim import DEFAULT_CLASSES, ClientPopulation
    pop = ClientPopulation(2_000_000, seed=3)
    ids = np.asarray([0, 1, 42, 1_999_999])
    np.testing.assert_array_equal(pop.device_class(ids),
                                  pop.device_class(ids))
    np.testing.assert_array_equal(pop.latency(ids, nonce=5),
                                  pop.latency(ids, nonce=5))
    assert (pop.latency(ids, nonce=5) > 0).all()
    assert not np.array_equal(pop.latency(ids, nonce=5),
                              pop.latency(ids, nonce=6))   # redraw per dispatch
    # class shares roughly follow the weights over a large id sample
    big = np.arange(20_000)
    shares = np.bincount(pop.device_class(big),
                         minlength=len(DEFAULT_CLASSES)) / big.size
    np.testing.assert_allclose(
        shares, [c.weight for c in DEFAULT_CLASSES], atol=0.02)
    # cohorts: distinct, available, deterministic in (t, nonce)
    c1 = pop.sample_cohort(16, t=100.0, nonce=2)
    c2 = pop.sample_cohort(16, t=100.0, nonce=2)
    np.testing.assert_array_equal(c1, c2)
    assert len(set(c1.tolist())) == len(c1)
    assert pop.available(c1, 100.0).all()


def test_starvation_raises():
    """A source that never produces clients raises instead of spinning."""
    fl = _fl("fedfa")
    index = flat.get_index(PARAMS)
    eng = AsyncEngine(flat.flatten(index, PARAMS), CFG, fl, index,
                      lambda d, t, k: None, KEY,
                      acfg=AsyncConfig(capacity=2, merge_k=1,
                                       max_retries=5))
    with pytest.raises(RuntimeError, match="starved"):
        for _ in range(100):
            eng.step()
